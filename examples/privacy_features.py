"""Privacy & communication features of the federated protocol.

Demonstrates, on a small federated ProdLDA run:
  1. secure aggregation — pairwise PRG masks hide every client's gradient
     from the server while the aggregate stays EXACTLY unchanged;
  2. local differential privacy — clip + Gaussian noise, utility trade-off;
  3. top-k gradient compression with error feedback — 10x fewer bytes on
     the wire per round, convergence preserved;
  4. FedAvg local steps — K x fewer synchronization rounds (the beyond-
     paper collective-volume optimization from EXPERIMENTS.md §Perf).

Run:  PYTHONPATH=src python examples/privacy_features.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import NTM, FederatedConfig, ModelConfig
from repro.core.aggregation import pairwise_mask
from repro.core.ntm import prodlda
from repro.core.protocol import ClientState, FedAvgTrainer, FederatedTrainer
from repro.data.synthetic_lda import generate_lda_corpus
from repro.optim import adam


def make_setup():
    cfg = ModelConfig(name="privacy-demo", kind=NTM, vocab_size=300,
                      num_topics=8, ntm_hidden=(48, 48))
    syn = generate_lda_corpus(vocab_size=300, num_topics=8, num_nodes=4,
                              shared_topics=2, docs_per_node=250,
                              val_docs_per_node=40, seed=3)
    loss = lambda p, b: prodlda.elbo_loss(p, cfg, b)  # noqa: E731
    init = prodlda.init_params(jax.random.PRNGKey(0), cfg)
    clients = [ClientState(data={"bow": b}, num_docs=len(b))
               for b in syn.node_bows]
    return cfg, loss, init, clients


def run_variant(name, fed, trainer_cls=FederatedTrainer, rounds=60):
    cfg, loss, init, clients = make_setup()
    tr = trainer_cls(loss, init, clients, fed, optimizer=adam(2e-3),
                     batch_size=48)
    for _ in range(rounds):
        tr.round()
    print(f"{name:34s} loss {tr.history[0]['loss']:8.2f} -> "
          f"{tr.history[-1]['loss']:8.2f}")
    return tr


def main():
    print("== masks cancel exactly ==")
    tree = {"w": jnp.zeros((64, 32))}
    key = jax.random.PRNGKey(0)
    masks = [pairwise_mask(tree, key, c, 4, scale=8.0) for c in range(4)]
    total = sum(np.abs(np.asarray(sum(m["w"] for m in masks))).max()
                for _ in [0])
    one = float(np.abs(np.asarray(masks[0]["w"])).max())
    print(f"per-client mask magnitude: {one:.2f}; "
          f"sum over clients: {total:.2e} (cancels)\n")

    print("== convergence under each privacy/communication mode ==")
    base = run_variant("baseline SyncOpt (paper)",
                       FederatedConfig(learning_rate=2e-3))
    run_variant("secure aggregation",
                FederatedConfig(learning_rate=2e-3,
                                secure_aggregation=True))
    run_variant("top-10% compression + err-fb",
                FederatedConfig(learning_rate=2e-3, compression_topk=0.1))
    run_variant("local DP (clip 1.0, sigma 0.3)",
                FederatedConfig(learning_rate=2e-3, dp_clip_norm=1.0,
                                dp_noise_multiplier=0.3))
    run_variant("FedAvg 4 local steps",
                FederatedConfig(learning_rate=2e-3, local_steps=4),
                trainer_cls=FedAvgTrainer, rounds=15)
    print("\n(secure-agg run must match baseline to float precision; "
          "compare the loss columns)")


if __name__ == "__main__":
    main()
