"""Serving demo: batched prefill + autoregressive decode across families.

Exercises the three cache disciplines in production serving:
  * full KV cache            (phi3 — dense GQA),
  * ring-buffer window cache (granite with the long_500k sliding-window
    variant — constant memory at any context length),
  * recurrent SSM state      (mamba2 — no KV cache at all).

Run:  PYTHONPATH=src python examples/serve_demo.py
"""
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import transformer as tfm


def demo(arch, *, window=0, prompt_len=48, max_new=16):
    cfg = get_config(arch).reduced()
    if window:
        cfg = dataclasses.replace(cfg, sliding_window=window)
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, prompt_len)),
                          jnp.int32)
    prefill = jax.jit(lambda p, b: tfm.prefill(
        p, cfg, b, dtype=jnp.float32, max_len=prompt_len + max_new))
    decode = jax.jit(lambda p, c, t: tfm.decode_step(p, cfg, c, t,
                                                     dtype=jnp.float32))
    logits, cache = prefill(params, {"tokens": prompts})
    tok = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)
    out = [tok]
    t0 = time.time()
    for _ in range(max_new - 1):
        logits, cache = decode(params, cache, tok)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(tok)
    dt = time.time() - t0
    cache_desc = {k: tuple(v.shape) for k, v in cache.items()
                  if hasattr(v, "shape") and v.ndim > 0}
    print(f"{arch:18s} window={window or '-':>5} "
          f"{2 * (max_new - 1) / dt:6.1f} tok/s  cache={cache_desc}")
    return np.asarray(jnp.concatenate(out, axis=1))


def main():
    print("arch               window  tok/s   cache layout")
    demo("phi3-mini-3.8b")                 # full KV cache
    demo("granite-34b", window=32)         # ring buffer (long-context mode)
    demo("mamba2-1.3b")                    # recurrent state only
    demo("minicpm3-4b")                    # MLA latent cache
    demo("hymba-1.5b")                     # hybrid: window KV + SSM state


if __name__ == "__main__":
    main()
