"""Paper Fig. 3 reproduction: collaborative vs non-collaborative topic
modeling on synthetic LDA data (paper §4.1).

Setting A: vary the number of shared topics K' at fixed eta = 0.01.
Setting B: vary the topic-prior eta at fixed K'.

For each setting we train (1) one non-collaborative ProdLDA per node and
(2) a centralized model on the concatenated corpus (scenario 2 — the paper
itself evaluates this scenario after checking gFedNTM matches it exactly;
we additionally assert that equality each run), then score DSS (Eq. 5,
lower better) and TSS (Eq. 6, closer to K better) against the known
generative ground truth, plus the paper's a-priori TSS baseline.

Default scale is reduced for CPU (documented in DESIGN.md §11); ``--full``
restores the paper's V=5000, K=50, 10k docs/node.
"""
from __future__ import annotations

import argparse
import json
import os
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import NTM, FederatedConfig, ModelConfig
from repro.core.ntm import prodlda
from repro.core.protocol import (ClientState, FederatedTrainer,
                                 train_centralized)
from repro.data.synthetic_lda import generate_lda_corpus
from repro.metrics import dss, tss, tss_baseline
from repro.optim import adam

REDUCED = dict(vocab_size=600, num_topics=12, num_nodes=3,
               docs_per_node=800, val_docs_per_node=120,
               steps=250, batch=64, lr=2e-3)
FULL = dict(vocab_size=5000, num_topics=50, num_nodes=5,
            docs_per_node=10_000, val_docs_per_node=1_000,
            steps=2000, batch=256, lr=2e-3)


def _cfg(scale) -> ModelConfig:
    return ModelConfig(name="prodlda-bench", kind=NTM,
                       vocab_size=scale["vocab_size"],
                       num_topics=scale["num_topics"],
                       ntm_hidden=(100, 100), ntm_dropout=0.2)


def _train_models(syn, scale, seed):
    """(per-node params list, centralized params) for one scenario."""
    cfg = _cfg(scale)
    loss = lambda p, b: prodlda.elbo_loss(p, cfg, b)  # noqa: E731

    node_params = []
    for l, bows in enumerate(syn.node_bows):
        init = prodlda.init_params(jax.random.PRNGKey(seed + 11 * l), cfg)
        node_params.append(train_centralized(
            loss, init, {"bow": bows}, optimizer=adam(scale["lr"]),
            batch_size=scale["batch"], steps=scale["steps"],
            seed=seed + 13 * l))

    init = prodlda.init_params(jax.random.PRNGKey(seed + 999), cfg)
    central = train_centralized(
        loss, init, {"bow": syn.concat_bows()}, optimizer=adam(scale["lr"]),
        batch_size=scale["batch"] * scale["num_nodes"],
        steps=scale["steps"], seed=seed + 777)
    return cfg, node_params, central


def _score(cfg, params, syn):
    beta = np.asarray(prodlda.get_topics(params))
    val_bow = jnp.asarray(syn.concat_val_bows())
    theta = np.asarray(prodlda.infer_theta(params, cfg, val_bow))
    return (dss(syn.concat_val_thetas(), theta),
            tss(syn.beta, beta))


def _score_node(cfg, params, syn, node):
    """Score a node's model on the SAME concatenated validation set the
    centralized model is scored on (as the paper does: all models infer
    the full validation corpus) — DSS scales with the number of docs, so
    mixed-size comparisons would be meaningless."""
    return _score(cfg, params, syn)


def check_federated_equals_centralized(syn, scale, seed=0) -> float:
    """The gFedNTM == centralized assertion the paper makes in §4.1."""
    cfg = _cfg(scale)
    loss = lambda p, b: prodlda.elbo_loss(p, cfg, b, train=False)  # noqa
    init = prodlda.init_params(jax.random.PRNGKey(seed), cfg)
    clients = [ClientState(data={"bow": b}, num_docs=len(b))
               for b in syn.node_bows]
    tr = FederatedTrainer(loss, init, clients,
                          FederatedConfig(learning_rate=1e-2),
                          batch_size=scale["batch"])
    key = jax.random.PRNGKey(seed)
    grads, ws, batches = [], [], []
    for l, c in enumerate(tr.clients):
        _, g, n = tr._client_grad(l, c, key)
        grads.append(g)
        ws.append(n)
        idx = np.asarray(jax.random.choice(
            jax.random.fold_in(key, l), c.num_docs, (scale["batch"],),
            replace=False))
        batches.append(c.data["bow"][idx])
    from repro.core.aggregation import aggregate_host
    g_fed = aggregate_host(grads, ws)
    g_cent = jax.grad(loss)(init,
                            {"bow": jnp.asarray(np.concatenate(batches))})
    return max(float(jnp.max(jnp.abs(a - b))) for a, b in zip(
        jax.tree_util.tree_leaves(g_fed), jax.tree_util.tree_leaves(g_cent)))


def run(full=False, runs=1, out_path="experiments/bench_synthetic.json",
        quick=False):
    scale = dict(FULL if full else REDUCED)
    if quick:
        scale.update(steps=150, docs_per_node=300, val_docs_per_node=60,
                     vocab_size=400)
    k = scale["num_topics"]
    setting_a = [max(k // 10, 1), k // 2] if quick \
        else [max(k // 10, 1), k // 4, k // 2, int(k * 0.8)]
    setting_b = [0.01] if quick else [0.01, 0.04, 1.0]
    results = {"scale": scale, "setting_A": [], "setting_B": [],
               "fed_equals_centralized_maxerr": None}

    t0 = time.time()
    for run_idx in range(runs):
        for kp in setting_a:
            syn = generate_lda_corpus(
                vocab_size=scale["vocab_size"], num_topics=k,
                num_nodes=scale["num_nodes"], shared_topics=kp, eta=0.01,
                docs_per_node=scale["docs_per_node"],
                val_docs_per_node=scale["val_docs_per_node"],
                seed=100 * run_idx + kp)
            cfg, nodes, central = _train_models(syn, scale, seed=run_idx)
            d_c, t_c = _score(cfg, central, syn)
            per_node = [_score_node(cfg, p, syn, i)
                        for i, p in enumerate(nodes)]
            rec = {"K_prime": kp, "run": run_idx,
                   "dss_central": d_c, "tss_central": t_c,
                   "dss_noncollab": float(np.mean([d for d, _ in per_node])),
                   "tss_noncollab": float(np.mean([t for _, t in per_node])),
                   "tss_baseline": tss_baseline(scale["vocab_size"], k,
                                                0.01, runs=3)}
            results["setting_A"].append(rec)
            print(f"[A] K'={kp:3d} run{run_idx} "
                  f"DSS c/nc={d_c:.3f}/{rec['dss_noncollab']:.3f}  "
                  f"TSS c/nc={t_c:.2f}/{rec['tss_noncollab']:.2f} "
                  f"(base {rec['tss_baseline']:.2f}, max {k})")
        for eta in setting_b:
            syn = generate_lda_corpus(
                vocab_size=scale["vocab_size"], num_topics=k,
                num_nodes=scale["num_nodes"],
                shared_topics=max(k // 5, 1), eta=eta,
                docs_per_node=scale["docs_per_node"],
                val_docs_per_node=scale["val_docs_per_node"],
                seed=991 * run_idx + int(eta * 1000))
            cfg, nodes, central = _train_models(syn, scale, seed=run_idx)
            d_c, t_c = _score(cfg, central, syn)
            per_node = [_score_node(cfg, p, syn, i)
                        for i, p in enumerate(nodes)]
            rec = {"eta": eta, "run": run_idx,
                   "dss_central": d_c, "tss_central": t_c,
                   "dss_noncollab": float(np.mean([d for d, _ in per_node])),
                   "tss_noncollab": float(np.mean([t for _, t in per_node])),
                   "tss_baseline": tss_baseline(scale["vocab_size"], k,
                                                eta, runs=3)}
            results["setting_B"].append(rec)
            print(f"[B] eta={eta:<5} run{run_idx} "
                  f"DSS c/nc={d_c:.3f}/{rec['dss_noncollab']:.3f}  "
                  f"TSS c/nc={t_c:.2f}/{rec['tss_noncollab']:.2f}")

    syn = generate_lda_corpus(
        vocab_size=scale["vocab_size"], num_topics=k,
        num_nodes=scale["num_nodes"], shared_topics=max(k // 5, 1),
        docs_per_node=scale["docs_per_node"],
        val_docs_per_node=scale["val_docs_per_node"], seed=5)
    err = check_federated_equals_centralized(syn, scale)
    results["fed_equals_centralized_maxerr"] = err
    print(f"federated == centralized gradient max err: {err:.2e}")
    results["wall_s"] = time.time() - t0

    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2)
    return results


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--runs", type=int, default=1)
    args = ap.parse_args(argv)
    run(full=args.full, runs=args.runs, quick=args.quick)


if __name__ == "__main__":
    main()
