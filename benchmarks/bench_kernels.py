"""Kernel-path microbenchmarks + oracle-deviation cells (CPU).

Wall-times on CPU do NOT represent TPU performance (the Pallas kernels
run in interpret mode); what IS meaningful here:
  * the pure-jnp production paths (chunked flash attention, SSD chunked
    scan, fused-vs-naive topic decoder) in steady jit state,
  * the aggregation hot-path cells (``kernels/ops.py`` wrappers) on BOTH
    kernel backends, each carrying ``max_dev_vs_ref`` — the measured
    deviation against the pure-jnp oracle (``kernels/ref.py``) that the
    CI gate hard-fails on,
  * the DERIVED column: analytic FLOPs and bytes per call, i.e. the
    roofline inputs the TPU projection uses.

The JSON payload mirrors ``bench_scenarios.py`` (one ``setup`` block,
median-timed cells, per-cell backend tag) so ``benchmarks/ci_gate.py``
gates both suites from the single committed baseline
(``benchmarks/baselines/BENCH_scenarios_ci.json``, which holds the
scenario ``results`` AND this suite's ``kernel_results``):

    PYTHONPATH=src python -m benchmarks.bench_kernels --quick \\
        --out experiments/bench_kernels_ci.json

JSON layout: {"suite": "kernels", "setup": {...}, "kernel_results":
[{"kernel", "backend", "us_per_call", "max_dev_vs_ref", "derived"}]}.
``max_dev_vs_ref`` is null for the timing-only LM cells (their parity
is pinned by tests/test_kernels.py, not re-measured here).
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as kops
from repro.kernels import ref
from repro.models.layers.attention import chunked_attention
from repro.models.layers.mamba2 import ssd_chunked


def _time(fn, *args, n=10):
    """Median microseconds/call after a compile-absorbing warmup call —
    the same median-not-mean policy as ``bench_scenarios._time_rounds``
    (one GC pause must not dominate a cell)."""
    out = fn(*args)
    jax.block_until_ready(out)
    per_call = []
    for _ in range(n):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        per_call.append(time.perf_counter() - t0)
    return float(np.median(per_call)) * 1e6


def _dev(a, b) -> float:
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    return max(float(jnp.max(jnp.abs(x.astype(jnp.float32)
                                     - y.astype(jnp.float32))))
               for x, y in zip(la, lb))


def _cell(kernel, backend, us, dev, derived):
    return {"kernel": kernel, "backend": backend, "us_per_call": us,
            "max_dev_vs_ref": dev, "derived": derived}


def _lm_cells(rng, quick):
    cells = []
    b, s, h, hkv, d = (1, 512, 4, 2, 64) if quick else (2, 1024, 8, 2, 64)

    q = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, hkv, d)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    flops = 4 * b * h * s * s * d // 2   # causal

    f_flash = jax.jit(lambda q, k, v: chunked_attention(
        q, k, v, pos, pos, causal=True, window=0, scale=d ** -0.5))
    cells.append(_cell(f"flash_attention_jnp_b{b}s{s}", "xla",
                       _time(f_flash, q, k, v), None,
                       f"flops={flops:.3e}"))

    f_ref = jax.jit(lambda q, k, v: ref.flash_attention_ref(
        jnp.moveaxis(q, 1, 2), jnp.moveaxis(k, 1, 2), jnp.moveaxis(v, 1, 2)))
    cells.append(_cell(f"sdpa_naive_b{b}s{s}", "xla",
                       _time(f_ref, q, k, v), None,
                       f"scores_bytes={b*h*s*s*4:.3e}"))

    # SSD
    hs, p, n_state = 4, 32, 32
    x = jnp.asarray(rng.standard_normal((b, s, hs, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.001, 0.1, (b, s, hs)), jnp.float32)
    a = jnp.asarray(-rng.uniform(0.5, 2, (hs,)), jnp.float32)
    bb = jnp.asarray(rng.standard_normal((b, s, n_state)), jnp.float32)
    cc = jnp.asarray(rng.standard_normal((b, s, n_state)), jnp.float32)
    f_ssd = jax.jit(lambda *t: ssd_chunked(*t, chunk=128))
    cells.append(_cell(f"ssd_chunked_b{b}s{s}", "xla",
                       _time(f_ssd, x, dt, a, bb, cc), None,
                       f"state_bytes={b*hs*p*n_state*4}"))
    f_naive = jax.jit(ref.ssd_scan_ref)
    cells.append(_cell(f"ssd_naive_scan_b{b}s{s}", "xla",
                       _time(f_naive, x, dt, a, bb, cc), None,
                       "sequential reference"))

    # topic decoder: fused (never materializes B x V logits) vs naive
    bt, kt, vt = (64, 20, 2000) if quick else (256, 50, 5000)
    theta = jax.nn.softmax(jnp.asarray(
        rng.standard_normal((bt, kt)), jnp.float32))
    beta = jnp.asarray(rng.standard_normal((kt, vt)), jnp.float32)
    bow = jnp.asarray(rng.poisson(0.1, (bt, vt)).astype(np.float32))
    f_naive_td = jax.jit(lambda *t: ref.topic_decoder_ref(*t))
    cells.append(_cell(f"topic_decoder_naive_B{bt}V{vt}", "xla",
                       _time(f_naive_td, theta, beta, bow), None,
                       f"logits_bytes={bt*vt*4}"))
    return cells


def _aggregation_cells(rng, quick):
    """The fed_aggregate hot path on both backends, oracle-deviated.

    One mixed-shape stacked cohort sized like a quick-bench federation;
    the Pallas timings are interpret-mode on CPU (NOT TPU-representative
    — the meaningful column is ``max_dev_vs_ref``)."""
    cells = []
    k, l, d = (4, 6, 2000) if quick else (16, 24, 20000)
    x = jnp.asarray(rng.standard_normal((k, d)), jnp.float32)
    # the combine gets a zero-weight (padded) row; dp_secure gets the
    # strictly positive weights — its mask term divides by the weights,
    # and a floored 1e-9 divisor would blow the masks up to 1e9 scale
    # where an absolute oracle deviation is meaningless
    w_pos = jnp.asarray(rng.uniform(0.5, 4.0, k), jnp.float32)
    w = w_pos.at[0].set(0.0)
    err = jnp.asarray(rng.standard_normal((l, d)), jnp.float32)
    ids = jnp.arange(k, dtype=jnp.int32)
    masks = jnp.asarray(rng.standard_normal((k, d)), jnp.float32)
    noise = jnp.asarray(rng.standard_normal((k, d)), jnp.float32)
    coef = jnp.asarray(rng.uniform(0.1, 1.0, k), jnp.float32)
    bytes_tag = f"cohort_bytes={k*d*4}"

    combine_ref = ref.fed_combine_ref(x, w)
    k_keep = max(d // 10, 1)
    topk_ref = ref.fed_topk_ef_ref(x, err[ids], k_keep)
    dpsec_ref = ref.fed_dp_secure_apply_ref(
        x, noise=noise, masks=masks, clip_coef=coef, weights=w_pos,
        noise_scale=0.3)

    for backend in kops.KERNEL_BACKENDS:
        f_comb = jax.jit(lambda t, wt, b=backend:
                         kops.fed_weighted_combine(t, wt, backend=b))
        cells.append(_cell(f"fed_weighted_combine_K{k}D{d}", backend,
                           _time(f_comb, {"g": x}, w),
                           _dev(f_comb({"g": x}, w)["g"], combine_ref),
                           bytes_tag))
        f_topk = jax.jit(lambda m, e, i, b=backend: kops.fed_topk_ef(
            {"g": m}, {"g": e}, i, frac=0.1, backend=b))
        sent, new_err = f_topk(x, err, ids)
        cells.append(_cell(f"fed_topk_ef_K{k}D{d}", backend,
                           _time(f_topk, x, err, ids),
                           max(_dev(sent["g"], topk_ref[0]),
                               _dev(new_err["g"], topk_ref[1])),
                           f"k_keep={k_keep}"))
        f_dpsec = jax.jit(lambda t, b=backend: kops.fed_dp_secure_apply(
            {"g": t}, noise={"g": noise}, masks={"g": masks},
            clip_coef=coef, weights=w_pos, noise_scale=0.3, backend=b))
        cells.append(_cell(f"fed_dp_secure_apply_K{k}D{d}", backend,
                           _time(f_dpsec, x),
                           _dev(f_dpsec(x)["g"], dpsec_ref),
                           bytes_tag))
    return cells


def run(out_path=None, *, quick=False, seed=0):
    rng = np.random.default_rng(seed)
    cells = _lm_cells(rng, quick) + _aggregation_cells(rng, quick)
    for c in cells:
        dev = ("-" if c["max_dev_vs_ref"] is None
               else f"{c['max_dev_vs_ref']:.1e}")
        print(f"{c['kernel']:32s} {c['backend']:6s} "
              f"{c['us_per_call']:10.1f}us dev={dev:8s} {c['derived']}")
    payload = {"suite": "kernels",
               "setup": {"quick": quick, "seed": seed,
                         "backend": jax.default_backend()},
               "kernel_results": cells}
    if out_path:
        os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
        with open(out_path, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"wrote {out_path} ({len(cells)} kernel cells)")
    return payload


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=None,
                    help="JSON payload path (omit for stdout only)")
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized shapes")
    ap.add_argument("--seed", type=int, default=0)
    a = ap.parse_args(argv)
    return run(a.out, quick=a.quick, seed=a.seed)


if __name__ == "__main__":
    main()
