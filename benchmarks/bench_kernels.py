"""Kernel-path microbenchmarks (CPU).

Wall-times on CPU do NOT represent TPU performance (the Pallas kernels run
in interpret mode); what IS meaningful here:
  * the pure-jnp production paths (chunked flash attention, SSD chunked
    scan, fused-vs-naive topic decoder) in steady jit state,
  * the DERIVED column: analytic FLOPs and bytes per call, i.e. the
    roofline inputs the TPU projection uses.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.models.layers.attention import chunked_attention
from repro.models.layers.mamba2 import ssd_chunked


def _time(fn, *args, n=10):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n * 1e6


def run(quick=False):
    rows = []
    rng = np.random.default_rng(0)
    b, s, h, hkv, d = (1, 512, 4, 2, 64) if quick else (2, 1024, 8, 2, 64)

    q = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, hkv, d)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    flops = 4 * b * h * s * s * d // 2   # causal

    f_flash = jax.jit(lambda q, k, v: chunked_attention(
        q, k, v, pos, pos, causal=True, window=0, scale=d ** -0.5))
    rows.append((f"flash_attention_jnp_b{b}s{s}", _time(f_flash, q, k, v),
                 f"flops={flops:.3e}"))

    f_ref = jax.jit(lambda q, k, v: ref.flash_attention_ref(
        jnp.moveaxis(q, 1, 2), jnp.moveaxis(k, 1, 2), jnp.moveaxis(v, 1, 2)))
    rows.append((f"sdpa_naive_b{b}s{s}", _time(f_ref, q, k, v),
                 f"scores_bytes={b*h*s*s*4:.3e}"))

    # SSD
    hs, p, n_state = 4, 32, 32
    x = jnp.asarray(rng.standard_normal((b, s, hs, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.001, 0.1, (b, s, hs)), jnp.float32)
    a = jnp.asarray(-rng.uniform(0.5, 2, (hs,)), jnp.float32)
    bb = jnp.asarray(rng.standard_normal((b, s, n_state)), jnp.float32)
    cc = jnp.asarray(rng.standard_normal((b, s, n_state)), jnp.float32)
    f_ssd = jax.jit(lambda *t: ssd_chunked(*t, chunk=128))
    rows.append((f"ssd_chunked_b{b}s{s}", _time(f_ssd, x, dt, a, bb, cc),
                 f"state_bytes={b*hs*p*n_state*4}"))
    f_naive = jax.jit(ref.ssd_scan_ref)
    rows.append((f"ssd_naive_scan_b{b}s{s}",
                 _time(f_naive, x, dt, a, bb, cc),
                 "sequential reference"))

    # topic decoder: fused (never materializes B x V logits) vs naive
    bt, kt, vt = (64, 20, 2000) if quick else (256, 50, 5000)
    theta = jax.nn.softmax(jnp.asarray(
        rng.standard_normal((bt, kt)), jnp.float32))
    beta = jnp.asarray(rng.standard_normal((kt, vt)), jnp.float32)
    bow = jnp.asarray(rng.poisson(0.1, (bt, vt)).astype(np.float32))
    f_naive_td = jax.jit(lambda *t: ref.topic_decoder_ref(*t))
    rows.append((f"topic_decoder_naive_B{bt}V{vt}",
                 _time(f_naive_td, theta, beta, bow),
                 f"logits_bytes={bt*vt*4}"))
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
