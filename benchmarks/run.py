"""Benchmark harness entry point — one benchmark per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows per the repo convention:
  * protocol microbenchmarks (gFedNTM round costs, Eq. 2 aggregation,
    secure-agg/compression overheads),
  * kernel-path timings with analytic roofline inputs,
  * Fig. 3 (synthetic DSS/TSS, quick setting) summary rows,
  * Fig. 4 (AMWMD, quick setting) summary rows,
  * round-engine participation x server-optimizer sweep (quick setting),
  * loop-vs-vmap cohort execution speedup (quick setting),
  * roofline-table availability from the dry-run artifacts.

Full-scale versions: ``python -m benchmarks.bench_synthetic --full`` etc.
"""
from __future__ import annotations

import sys
import time

import numpy as np


def main() -> None:
    rows = []

    from benchmarks import bench_protocol
    rows += bench_protocol.run(quick=True)

    from benchmarks import bench_kernels
    rows += bench_kernels.run(quick=True)

    # paper Fig. 3 (quick scale): report the headline comparisons
    from benchmarks import bench_synthetic
    t0 = time.time()
    res = bench_synthetic.run(quick=True,
                              out_path="experiments/bench_synthetic.json")
    dt = (time.time() - t0) * 1e6
    a = res["setting_A"]
    rows.append(("fig3_dss_gain_smallKprime", dt / max(len(a), 1),
                 f"central={a[0]['dss_central']:.3f},"
                 f"noncollab={a[0]['dss_noncollab']:.3f}"))
    rows.append(("fig3_tss_gain_smallKprime", dt / max(len(a), 1),
                 f"central={a[0]['tss_central']:.2f},"
                 f"noncollab={a[0]['tss_noncollab']:.2f},"
                 f"baseline={a[0]['tss_baseline']:.2f}"))
    rows.append(("fig3_fed_eq_centralized", 0.0,
                 f"max_grad_err={res['fed_equals_centralized_maxerr']:.2e}"))

    # paper Fig. 4 (quick scale)
    from benchmarks import bench_wmd
    t0 = time.time()
    wres = bench_wmd.run(quick=True,
                         out_path="experiments/bench_wmd.json")
    dt = (time.time() - t0) * 1e6
    fed_keys = [k for k in wres["amwmd"] if k.startswith("federated")]
    fed_avg = min(float(np.mean(wres["amwmd"][k])) for k in fed_keys)
    rows.append(("fig4_amwmd_federated_avg", dt, f"avg={fed_avg:.3f},"
                 f"claim_holds={wres['fig4_claim_holds']}"))

    # round engine (quick scale): participation x server-optimizer sweep
    from benchmarks import bench_rounds
    t0 = time.time()
    rres = bench_rounds.run("experiments/bench_rounds_quick.json",
                            vocab=300, topics=5, docs=80, nodes=3, rounds=6,
                            batch=16, participation=(1.0, 0.67),
                            server_opts=("fedavg", "fedadam"),
                            staleness=({"straggler_prob": 0.0,
                                        "max_staleness": 0},))
    dt = (time.time() - t0) * 1e6
    cells = rres["results"]
    best = min(cells, key=lambda c: c["heldout_elbo_per_token"])
    rows.append(("rounds_sweep_quick", dt / max(len(cells), 1),
                 f"cells={len(cells)},best={best['server_optimizer']}"
                 f"@K{best['clients_per_round']},"
                 f"elbo/token={best['heldout_elbo_per_token']:.2f}"))

    # vectorized cohort execution (quick scale): loop vs vmap per-round cost
    from benchmarks import bench_clients
    t0 = time.time()
    cres = bench_clients.run("experiments/bench_clients_quick.json",
                             vocab=200, topics=5, hidden=32,
                             docs_per_client=40, batch=16, rounds=2,
                             k_sweep=(4,), e_sweep=(1,))
    dt = (time.time() - t0) * 1e6
    cell = cres["results"][0]
    rows.append(("clients_vmap_speedup_quick", dt,
                 f"K={cell['clients_per_round']},E={cell['local_epochs']},"
                 f"speedup={cell['speedup']:.1f}x,"
                 f"dev={cell['max_param_dev']:.1e}"))

    # scenario suite (quick scale): fused straggler ring buffer + non-IID
    from benchmarks import bench_scenarios
    t0 = time.time()
    sres = bench_scenarios.run("experiments/bench_scenarios_quick.json",
                               vocab=200, topics=5, hidden=32,
                               num_clients=4, docs_per_client=40, batch=16,
                               rounds=3,
                               scenarios=("sync", "straggler",
                                          "dirichlet-noniid"))
    dt = (time.time() - t0) * 1e6
    ratio = sres["straggler_over_sync_vmap"]
    devs = [c["max_param_dev"] for c in sres["results"]
            if "max_param_dev" in c]
    rows.append(("scenarios_quick", dt / max(len(sres["results"]), 1),
                 f"cells={len(sres['results'])},"
                 f"straggler/sync={ratio:.2f}x,"
                 f"max_dev={max(devs):.1e}"))

    # mesh-sharded cohort execution (quick scale): sharded-vs-unsharded
    # parity + wall-clock ratio on whatever device mesh the host can
    # build — a 1-device host reports the cell as skipped rather than
    # dropping the row
    import jax
    if jax.device_count() >= 2:
        t0 = time.time()
        mres = bench_scenarios.run("experiments/bench_mesh_quick.json",
                                   vocab=200, topics=5, hidden=32,
                                   num_clients=4, docs_per_client=40,
                                   batch=16, rounds=3,
                                   scenarios=("mesh-sync",))
        dt = (time.time() - t0) * 1e6
        cell = mres["results"][0]
        rows.append(("mesh_sharded_quick", dt,
                     f"mesh={cell['mesh_shape']},"
                     f"sharded_dev={cell['backend_param_dev']:.1e},"
                     f"shard/vmap={cell['shard_over_single_vmap']:.2f}x"))
    else:
        rows.append(("mesh_sharded_quick", 0.0,
                     "skipped=1_device_host (export XLA_FLAGS="
                     "--xla_force_host_platform_device_count=8)"))

    # roofline artifacts (built by the dry-run, reported by roofline.py)
    from benchmarks import roofline
    reports = roofline.load_reports()
    rows.append(("roofline_pairs_available", 0.0,
                 f"n={len(reports)} (see EXPERIMENTS.md)"))

    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
