"""CI perf/regression gate for the scenario- and kernel-suite payloads.

Compares a freshly-produced bench JSON (``bench_scenarios``,
``bench_kernels``, ``bench_serve`` or ``bench_load`` — the gate is
suite-aware, keyed on which of ``results`` / ``kernel_results`` /
``serve_results`` / ``load_results`` the payload carries; the single
committed baseline ``benchmarks/baselines/BENCH_scenarios_ci.json``
holds ALL FOUR) and enforces a two-tier policy:

  * HARD FAIL (exit 1) — correctness/privacy invariants.  These do not
    drift with runner noise, so any violation is a real regression:
      - ``max_param_dev >= 1e-5`` in any scenario (loop/vmap parity,
        transforms included);
      - ``backend_param_dev`` / ``backend_loss_dev >= 1e-5`` in any
        ``pallas-*`` scenario (the vmap run on the Pallas kernel
        backend drifted from the SAME vmap run on the XLA reference)
        or any ``mesh-*`` scenario (the mesh-sharded vmap run drifted
        from the SAME spec unsharded);
      - a cell marked ``skipped`` whose recorded mesh size the host
        could actually build (``setup.device_count`` large enough) —
        a skip is only legitimate when the devices are truly absent;
        legitimately-skipped mesh cells warn and keep baseline
        membership satisfied (the host-mesh CI leg provides the real
        coverage);
      - ``secure_mask_sum_abs != 0.0`` or
        ``secure_mask_sum_abs_pallas != 0.0`` (the bitwise secure-mask
        cancellation invariant, probed both through plain jnp summation
        and INSIDE the Pallas combine kernel's block-tiled accumulation);
      - ``secure_mask_sum_abs_mesh != 0.0``, or the key missing from a
        payload produced with >= 2 visible devices (the same invariant
        through per-device partial sums + a cross-device psum — exact
        because every partial is a dyadic-grid integer, DESIGN.md);
      - ``vmap_traces > 1`` for any scenario (the fixed-K retrace-free
        contract — a second trace means the fused path silently
        degenerated to per-cohort-size recompiles);
      - a kernel cell's ``max_dev_vs_ref >= 1e-5`` (a Pallas or XLA
        aggregation path drifted from its pure-jnp oracle,
        ``kernels/ref.py``);
      - the serve suite's ``sync-equivalence`` anchor missing or its
        ``final_param_dev >= 1e-5`` (the buffered-async service with
        M=K / staleness 0 / in-order arrivals must reproduce the sync
        FedAvg trajectory, DESIGN.md §6), a serve cell recording a
        rejection reason outside ``repro.serve.REJECT_REASONS``, zero
        aggregations, or a train-serve cell with zero inference calls;
      - the load suite's ``wire-sync-equivalence`` anchor missing or its
        ``final_param_dev >= 1e-5`` (the same anchor crossed over a real
        localhost socket through the repro.net codec), a ``load_results``
        cell recording an unnamed rejection reason or zero aggregations,
        or the ``wire-load`` cell running under 4 client processes,
        recording zero inference calls, or missing any of its
        p50/p95/p99 upload/infer latency columns (the SLO measurement
        silently stopped);
      - a scenario or kernel cell present in the baseline missing from
        the current payload (a silently-shrunk grid reads as "all
        green"); baseline ``mesh-*`` cells are exempt only on hosts
        whose ``setup.device_count`` cannot build the recorded mesh —
        the host-mesh CI leg still hard-requires them.
  * WARN ONLY (``::warning::`` annotations, exit 0) — timing trends.
    Shared CI runners are noisy, so these inform rather than block:
      - ``straggler_over_sync_vmap`` worsened beyond the allowed ratio
        over baseline;
      - any scenario's vmap seconds/round or loop/vmap speedup, or any
        kernel cell's us/call, worsened beyond the allowed ratio.

The gate's notion of "a scenario" is the NAMED registry of
``repro.api.registry`` — a payload scenario the registry does not know
is a hard failure (the bench and the declarative API drifted), and the
``--spec-validate`` mode round-trips every registry scenario and every
JSON spec under ``examples/specs/`` through the
``FederationSpec`` validator (``from_dict(to_dict()) == spec``, JSON
round trip included) so an invalid or unserializable scenario can never
land.

Usage (what .github/workflows/ci.yml runs):

    python -m benchmarks.ci_gate experiments/bench_scenarios_ci.json \\
        benchmarks/baselines/BENCH_scenarios_ci.json
    python -m benchmarks.ci_gate experiments/bench_kernels_ci.json \\
        benchmarks/baselines/BENCH_scenarios_ci.json
    python -m benchmarks.ci_gate experiments/bench_serve_ci.json \\
        benchmarks/baselines/BENCH_scenarios_ci.json
    python -m benchmarks.ci_gate experiments/bench_load_ci.json \\
        benchmarks/baselines/BENCH_scenarios_ci.json
    python -m benchmarks.ci_gate --spec-validate
"""
from __future__ import annotations

import argparse
import json
import os
import sys

DEV_BOUND = 1e-5
TIMING_SLACK = 2.0       # warn when current > slack * baseline
SPECS_DIR = "examples/specs"


def _warn(msg: str) -> None:
    # GitHub Actions annotation; plain stderr elsewhere
    print(f"::warning::{msg}")


def _gate_kernels(current: dict, baseline: dict, *, dev_bound: float,
                  timing_slack: float) -> list:
    """Hard/warn policy for a ``bench_kernels`` payload: oracle
    deviation and cell membership are hard, us/call trends warn-only.
    Cells are keyed (kernel, backend) — the xla and pallas rows of the
    same kernel are independent gate cells."""
    failures = []
    cur = {(r["kernel"], r["backend"]): r
           for r in current.get("kernel_results", [])}
    base = {(r["kernel"], r["backend"]): r
            for r in baseline.get("kernel_results", [])}
    for key in base:
        if key not in cur:
            failures.append(f"kernel cell {key!r} present in baseline "
                            "but missing from the current payload")
    for key, r in cur.items():
        dev = r.get("max_dev_vs_ref")
        if dev is not None and not dev < dev_bound:
            failures.append(f"{key}: max_dev_vs_ref={dev!r} (bound "
                            f"{dev_bound:g}) — the kernel drifted from "
                            "its pure-jnp oracle (kernels/ref.py)")
        b = base.get(key)
        if b and r.get("us_per_call") and b.get("us_per_call"):
            if r["us_per_call"] > timing_slack * b["us_per_call"]:
                _warn(f"{key}: us_per_call {r['us_per_call']:.4g} vs "
                      f"baseline {b['us_per_call']:.4g} (beyond "
                      f"{timing_slack:g}x slack)")
    return failures


# the documented rejection ledger of the buffered-async service; kept
# importable-free (the trend gate's stdlib-only contract) with the live
# tuple preferred when repro IS on the path.  malformed / wire_version
# are the net layer's decode refusals (repro.net.codec).
_REJECT_REASONS_FALLBACK = ("stale", "superseded", "unknown_client",
                            "draining", "zero_weight", "bad_version",
                            "upload_failed", "malformed", "wire_version")


def _gate_serve(current: dict, baseline: dict, *, dev_bound: float,
                timing_slack: float) -> list:
    """Hard/warn policy for a ``bench_serve`` payload: the M=K /
    staleness-0 sync-equivalence anchor, rejection-ledger naming, and
    cell membership are hard; throughput/latency trends warn-only."""
    failures = []
    try:
        from repro.serve import REJECT_REASONS
    except ImportError:
        REJECT_REASONS = _REJECT_REASONS_FALLBACK
        _warn("repro.serve not importable (set PYTHONPATH=src) — gating "
              "rejection reasons against the vendored fallback tuple")
    cur = {r["cell"]: r for r in current.get("serve_results", [])}
    base = {r["cell"]: r for r in baseline.get("serve_results", [])}
    for name in base:
        if name not in cur:
            failures.append(f"serve cell {name!r} present in baseline "
                            "but missing from the current payload")
    eq = cur.get("sync-equivalence")
    if eq is None:
        failures.append("serve payload carries no 'sync-equivalence' "
                        "cell — the anchor must be measured every run")
    else:
        dev = eq.get("final_param_dev")
        if dev is None or not dev < dev_bound:
            failures.append(
                f"sync-equivalence: final_param_dev={dev!r} (bound "
                f"{dev_bound:g}) — the buffered-async service with M=K, "
                "max_staleness=0 and in-order arrivals must reproduce "
                "the synchronous FedAvg trajectory (DESIGN.md §6)")
    for name, r in cur.items():
        unknown = sorted(set(r.get("rejections", {})) -
                         set(REJECT_REASONS))
        if unknown:
            failures.append(
                f"{name}: rejection reason(s) {unknown} are not in "
                "repro.serve.REJECT_REASONS — every rejection path must "
                "be named and documented")
        if not r.get("aggregations"):
            failures.append(f"{name}: zero aggregations — the service "
                            "never advanced the model")
        if name == "train-serve" and not r.get("infer_calls"):
            failures.append("train-serve: zero inference calls recorded "
                            "— the serve-side measurement silently "
                            "stopped")
        b = base.get(name)
        if not b:
            continue
        for key, worse_is in (("uploads_per_s", "lower"),
                              ("infer_throughput_per_s", "lower"),
                              ("infer_latency_p50_s", "higher")):
            c_v, b_v = r.get(key), b.get(key)
            if not (c_v and b_v):
                continue
            degraded = (c_v > timing_slack * b_v if worse_is == "higher"
                        else c_v * timing_slack < b_v)
            if degraded:
                _warn(f"{name}: {key} {c_v:.4g} vs baseline {b_v:.4g} "
                      f"(beyond {timing_slack:g}x slack)")
    return failures


def _gate_load(current: dict, baseline: dict, *, dev_bound: float,
               timing_slack: float) -> list:
    """Hard/warn policy for a ``bench_load`` payload: the over-the-wire
    sync-equivalence anchor, rejection-ledger naming, >= 4 concurrent
    processes and latency-column presence are hard; the latency and
    throughput VALUES trend warn-only (shared runners are noisy)."""
    failures = []
    try:
        from repro.serve import REJECT_REASONS
    except ImportError:
        REJECT_REASONS = _REJECT_REASONS_FALLBACK
        _warn("repro.serve not importable (set PYTHONPATH=src) — gating "
              "rejection reasons against the vendored fallback tuple")
    cur = {r["cell"]: r for r in current.get("load_results", [])}
    base = {r["cell"]: r for r in baseline.get("load_results", [])}
    for name in base:
        if name not in cur:
            failures.append(f"load cell {name!r} present in baseline "
                            "but missing from the current payload")
    eq = cur.get("wire-sync-equivalence")
    if eq is None:
        failures.append("load payload carries no 'wire-sync-equivalence' "
                        "cell — the anchor must cross the wire every run")
    else:
        dev = eq.get("final_param_dev")
        if dev is None or not dev < dev_bound:
            failures.append(
                f"wire-sync-equivalence: final_param_dev={dev!r} (bound "
                f"{dev_bound:g}) — M=K / staleness-0 / in-order localhost "
                "uploads must reproduce the sync FedAvg trajectory "
                "through encode -> TCP -> decode (DESIGN.md §6)")
    for name, r in cur.items():
        unknown = sorted(set(r.get("rejections", {})) -
                         set(REJECT_REASONS))
        if unknown:
            failures.append(
                f"{name}: rejection reason(s) {unknown} are not in "
                "repro.serve.REJECT_REASONS — every rejection path must "
                "be named and documented")
        if not r.get("aggregations"):
            failures.append(f"{name}: zero aggregations — the service "
                            "never advanced the model")
        if name == "wire-load":
            if (r.get("procs") or 0) < 4:
                failures.append(
                    f"wire-load: {r.get('procs')!r} client processes — "
                    "the latency-under-load SLO is defined under >= 4 "
                    "concurrent processes")
            if not r.get("infer_calls"):
                failures.append("wire-load: zero inference calls recorded "
                                "— the serve-side measurement silently "
                                "stopped")
            for key in ("upload_p50_s", "upload_p95_s", "upload_p99_s",
                        "infer_p50_s", "infer_p95_s", "infer_p99_s"):
                if not r.get(key):
                    failures.append(
                        f"wire-load: {key} missing — the SLO columns "
                        "must be measured every run (their values trend "
                        "warn-only, their presence is the contract)")
        b = base.get(name)
        if not b:
            continue
        for key, worse_is in (("aggs_per_s", "lower"),
                              ("uploads_per_s", "lower"),
                              ("upload_p50_s", "higher"),
                              ("upload_p95_s", "higher"),
                              ("upload_p99_s", "higher"),
                              ("infer_p50_s", "higher")):
            c_v, b_v = r.get(key), b.get(key)
            if not (c_v and b_v):
                continue
            degraded = (c_v > timing_slack * b_v if worse_is == "higher"
                        else c_v * timing_slack < b_v)
            if degraded:
                _warn(f"{name}: {key} {c_v:.4g} vs baseline {b_v:.4g} "
                      f"(beyond {timing_slack:g}x slack)")
    return failures


def gate(current: dict, baseline: dict, *,
         dev_bound: float = DEV_BOUND,
         timing_slack: float = TIMING_SLACK) -> int:
    # suite dispatch: a bench_serve payload carries serve_results, a
    # bench_load payload load_results, a bench_kernels payload
    # kernel_results (and no scenario results) — all gate against the
    # SAME baseline file's matching block
    if "load_results" in current and "results" not in current:
        failures = _gate_load(current, baseline, dev_bound=dev_bound,
                              timing_slack=timing_slack)
        if failures:
            for f in failures:
                print(f"FAIL: {f}", file=sys.stderr)
            return 1
        n = len(current.get("load_results", []))
        print(f"ci_gate: {n} load cells pass (wire anchor "
              f"dev<{dev_bound:g}, >=4-process SLO columns measured, "
              "rejection ledger fully named); latency deltas warn-only")
        return 0
    if "serve_results" in current and "results" not in current:
        failures = _gate_serve(current, baseline, dev_bound=dev_bound,
                               timing_slack=timing_slack)
        if failures:
            for f in failures:
                print(f"FAIL: {f}", file=sys.stderr)
            return 1
        n = len(current.get("serve_results", []))
        print(f"ci_gate: {n} serve cells pass (sync-equivalence anchor "
              f"dev<{dev_bound:g}, rejection ledger fully named); "
              "throughput/latency deltas warn-only")
        return 0
    if "kernel_results" in current and "results" not in current:
        failures = _gate_kernels(current, baseline, dev_bound=dev_bound,
                                 timing_slack=timing_slack)
        if failures:
            for f in failures:
                print(f"FAIL: {f}", file=sys.stderr)
            return 1
        n = len(current.get("kernel_results", []))
        print(f"ci_gate: {n} kernel cells pass (dev_vs_ref<{dev_bound:g} "
              "per backend); timing deltas warn-only")
        return 0

    failures = []
    cur = {r["scenario"]: r for r in current.get("results", [])}
    base = {r["scenario"]: r for r in baseline.get("results", [])}

    # ---- hard gates: correctness / privacy / retrace contract -----------
    dev_count = current.get("setup", {}).get("device_count", 1)
    for name, b in base.items():
        if name not in cur:
            # baseline mesh cells are exempt ONLY on hosts that cannot
            # build the recorded mesh (the 1-device smoke legs); the
            # host-mesh CI leg, whose payload records enough devices,
            # still hard-requires them
            mesh_n = (b.get("mesh_shape") or {}).get("data", 0)
            if mesh_n and mesh_n > dev_count:
                _warn(f"baseline scenario {name!r} needs a "
                      f"{mesh_n}-device mesh, current host has "
                      f"{dev_count} — membership waived for this leg")
                continue
            failures.append(f"scenario {name!r} present in baseline but "
                            "missing from the current payload")
    # the gate's cells ARE the named registry scenarios — a payload name
    # the registry doesn't know means the bench and the API drifted.
    # The trend gate itself stays runnable in a stdlib-only env (its
    # pre-PR-5 contract): if repro isn't importable the membership
    # check is skipped with a warning, never a traceback.
    try:
        from repro.api.registry import SCENARIOS
    except ImportError:
        SCENARIOS = None
        _warn("repro.api not importable (set PYTHONPATH=src) — skipping "
              "the registry-membership gate")
    if SCENARIOS is not None:
        unregistered = sorted(set(cur) - set(SCENARIOS))
        if unregistered:
            failures.append(
                f"scenario(s) {unregistered} in the payload are not in "
                "the named registry (repro.api.registry.SCENARIOS) — "
                "bench cells must be registry scenarios")
    for name, r in cur.items():
        if "skipped" in r:
            # a mesh cell the host could not build: legitimate ONLY
            # when the recorded mesh is larger than the visible device
            # count — anything else is a silently-dropped cell
            mesh_n = (r.get("mesh_shape") or {}).get("data", 0)
            if mesh_n and mesh_n > dev_count:
                _warn(f"{name}: skipped ({r['skipped']}) — the "
                      "host-mesh CI leg provides the real coverage")
            else:
                failures.append(
                    f"{name}: marked skipped ({r.get('skipped')!r}) but "
                    f"the host had {dev_count} device(s) for a "
                    f"mesh of {mesh_n or '?'} — a runnable cell must "
                    "run")
            continue
        dev = r.get("max_param_dev")
        if dev is None or not dev < dev_bound:
            failures.append(f"{name}: max_param_dev={dev!r} (bound "
                            f"{dev_bound:g}) — loop/vmap parity broke")
        # pallas-backend cells carry the DIRECT xla-vs-pallas vmap
        # deviations, mesh cells the sharded-vs-unsharded ones; a cell
        # missing them means the bench silently stopped measuring
        is_mesh = bool(r.get("mesh_shape"))
        if is_mesh or r.get("kernel_backend") == "pallas":
            what = ("the mesh-sharded vmap run drifted from the same "
                    "spec unsharded" if is_mesh else
                    "the Pallas aggregation backend drifted from the "
                    "XLA reference on the same vmap path")
            for key in ("backend_param_dev", "backend_loss_dev"):
                bdev = r.get(key)
                if bdev is None or not bdev < dev_bound:
                    failures.append(
                        f"{name}: {key}={bdev!r} (bound {dev_bound:g}) "
                        f"— {what}")
        traces = r.get("vmap_traces")
        if traces is not None and traces > 1:
            failures.append(f"{name}: vmap_traces={traces} — the fixed-K "
                            "fused graph retraced (contract: exactly one "
                            "compile per run)")
    mask_sum = current.get("secure_mask_sum_abs")
    if mask_sum != 0.0:
        failures.append(f"secure_mask_sum_abs={mask_sum!r} — secure-mask "
                        "cancellation must be bitwise exact (0.0)")
    # the same invariant probed through the Pallas combine kernel's
    # block-tiled accumulation (key absent from pre-PR-7 payloads)
    if "secure_mask_sum_abs_pallas" in current:
        mask_sum_pl = current["secure_mask_sum_abs_pallas"]
        if mask_sum_pl != 0.0:
            failures.append(
                f"secure_mask_sum_abs_pallas={mask_sum_pl!r} — the "
                "in-kernel client-axis sum broke the bitwise secure-mask "
                "cancellation (dyadic-grid invariant)")
    # ... and through the SHARDED combine (per-device partials + psum):
    # required whenever the producing host could build a >= 2-device
    # mesh — a multi-device payload without the probe means the bench
    # silently stopped checking the cross-device invariant
    if "secure_mask_sum_abs_mesh" in current:
        mask_sum_mesh = current["secure_mask_sum_abs_mesh"]
        if mask_sum_mesh != 0.0:
            failures.append(
                f"secure_mask_sum_abs_mesh={mask_sum_mesh!r} — the "
                "cross-device partial-sum + psum path broke the bitwise "
                "secure-mask cancellation (every per-device partial is "
                "an exact dyadic-grid integer, so the psum is exact; "
                "DESIGN.md)")
    elif current.get("setup", {}).get("device_count", 1) >= 2:
        failures.append(
            "secure_mask_sum_abs_mesh missing from a payload produced "
            f"with {current['setup']['device_count']} visible devices — "
            "the sharded-combine cancellation probe must run whenever "
            "the host can build a mesh")

    # ---- warn-only trend gates: timings -------------------------------
    ratio, base_ratio = (current.get("straggler_over_sync_vmap"),
                         baseline.get("straggler_over_sync_vmap"))
    if ratio is not None and base_ratio:
        if ratio > timing_slack * base_ratio:
            _warn(f"straggler_over_sync_vmap {ratio:.2f} vs baseline "
                  f"{base_ratio:.2f} (> {timing_slack:g}x) — the fused "
                  "ring buffer may be paying host round-trips again")
    for name, r in cur.items():
        b = base.get(name)
        if not b:
            continue
        for key, worse_is in (("vmap_s_per_round", "higher"),
                              ("speedup", "lower")):
            c_v, b_v = r.get(key), b.get(key)
            if not (c_v and b_v):
                continue
            degraded = (c_v > timing_slack * b_v if worse_is == "higher"
                        else c_v * timing_slack < b_v)
            if degraded:
                _warn(f"{name}: {key} {c_v:.4g} vs baseline {b_v:.4g} "
                      f"(beyond {timing_slack:g}x slack)")

    if failures:
        for f in failures:
            print(f"FAIL: {f}", file=sys.stderr)
        return 1
    print(f"ci_gate: {len(cur)} scenarios pass "
          f"(dev<{dev_bound:g}, secure masks bitwise-cancelled, "
          "single-trace fixed-K); timing deltas warn-only")
    return 0


def spec_validate(specs_dir: str = SPECS_DIR) -> int:
    """Round-trip every registry scenario and every ``examples/specs``
    JSON file through the FederationSpec validator.

    Hard-fails (exit 1) when a scenario fails validation, when
    ``from_dict(to_dict())`` / JSON round-tripping drifts, or when the
    specs directory is missing — the step must stay honest even if the
    example files are deleted.
    """
    from repro.api.registry import SCENARIOS, scenario_spec
    from repro.api.spec import FederationSpec

    failures = []
    for name in sorted(SCENARIOS):
        try:
            s = scenario_spec(name)
            if FederationSpec.from_dict(s.to_dict()) != s:
                failures.append(f"registry scenario {name!r}: "
                                "from_dict(to_dict()) round-trip drifted")
            if FederationSpec.from_json(s.to_json()) != s:
                failures.append(f"registry scenario {name!r}: JSON "
                                "round-trip drifted")
        except Exception as e:  # validator errors included
            failures.append(f"registry scenario {name!r}: {e}")

    files = []
    if os.path.isdir(specs_dir):
        files = sorted(f for f in os.listdir(specs_dir)
                       if f.endswith(".json"))
        if not files:
            failures.append(f"no *.json specs under {specs_dir!r} — the "
                            "example specs are part of the contract")
        for fn in files:
            path = os.path.join(specs_dir, fn)
            try:
                s = FederationSpec.load(path)
                if FederationSpec.from_dict(s.to_dict()) != s:
                    failures.append(f"{path}: from_dict(to_dict()) "
                                    "round-trip drifted")
                # a spec file named after a registry scenario must BE
                # that scenario — docs point at both interchangeably
                stem = os.path.splitext(fn)[0]
                if stem in SCENARIOS and s != scenario_spec(stem):
                    failures.append(
                        f"{path}: drifted from registry scenario "
                        f"{stem!r} — regenerate it with "
                        f"scenario_spec({stem!r}).save(...)")
            except Exception as e:
                failures.append(f"{path}: {e}")
    else:
        failures.append(f"spec directory {specs_dir!r} missing")

    if failures:
        for f in failures:
            print(f"FAIL: {f}", file=sys.stderr)
        return 1
    print(f"spec-validate: {len(SCENARIOS)} registry scenarios + "
          f"{len(files)} spec file(s) under {specs_dir} round-trip "
          "through the validator")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("current", nargs="?",
                    help="freshly produced bench payload")
    ap.add_argument("baseline", nargs="?",
                    help="committed BENCH_*.json baseline")
    ap.add_argument("--dev-bound", type=float, default=DEV_BOUND)
    ap.add_argument("--timing-slack", type=float, default=TIMING_SLACK)
    ap.add_argument("--spec-validate", action="store_true",
                    help="round-trip every registry scenario and every "
                         f"JSON spec under --specs-dir ({SPECS_DIR}) "
                         "through the FederationSpec validator")
    ap.add_argument("--specs-dir", default=SPECS_DIR)
    a = ap.parse_args(argv)
    if a.spec_validate:
        if a.current or a.baseline:
            ap.error("--spec-validate is a standalone mode — payload "
                     "arguments would be silently ignored; run the "
                     "trend gate as a separate invocation")
        return spec_validate(a.specs_dir)
    if not (a.current and a.baseline):
        ap.error("current and baseline payload paths are required "
                 "(or pass --spec-validate)")
    with open(a.current) as f:
        current = json.load(f)
    with open(a.baseline) as f:
        baseline = json.load(f)
    return gate(current, baseline, dev_bound=a.dev_bound,
                timing_slack=a.timing_slack)


if __name__ == "__main__":
    sys.exit(main())
