"""CI perf/regression gate for the scenario-suite bench payloads.

Compares a freshly-produced ``bench_scenarios`` JSON against the
committed baseline (``benchmarks/baselines/BENCH_scenarios_ci.json``)
and enforces a two-tier policy:

  * HARD FAIL (exit 1) — correctness/privacy invariants.  These do not
    drift with runner noise, so any violation is a real regression:
      - ``max_param_dev >= 1e-5`` in any scenario (loop/vmap parity,
        transforms included);
      - ``secure_mask_sum_abs != 0.0`` (the bitwise secure-mask
        cancellation invariant);
      - ``vmap_traces > 1`` for any scenario (the fixed-K retrace-free
        contract — a second trace means the fused path silently
        degenerated to per-cohort-size recompiles);
      - a scenario present in the baseline missing from the current
        payload (a silently-shrunk grid reads as "all green").
  * WARN ONLY (``::warning::`` annotations, exit 0) — timing trends.
    Shared CI runners are noisy, so these inform rather than block:
      - ``straggler_over_sync_vmap`` worsened beyond the allowed ratio
        over baseline;
      - any scenario's vmap seconds/round or loop/vmap speedup worsened
        beyond the allowed ratio.

Usage (what .github/workflows/ci.yml runs):

    python -m benchmarks.ci_gate experiments/bench_scenarios_ci.json \\
        benchmarks/baselines/BENCH_scenarios_ci.json
"""
from __future__ import annotations

import argparse
import json
import sys

DEV_BOUND = 1e-5
TIMING_SLACK = 2.0       # warn when current > slack * baseline


def _warn(msg: str) -> None:
    # GitHub Actions annotation; plain stderr elsewhere
    print(f"::warning::{msg}")


def gate(current: dict, baseline: dict, *,
         dev_bound: float = DEV_BOUND,
         timing_slack: float = TIMING_SLACK) -> int:
    failures = []
    cur = {r["scenario"]: r for r in current.get("results", [])}
    base = {r["scenario"]: r for r in baseline.get("results", [])}

    # ---- hard gates: correctness / privacy / retrace contract -----------
    for name in base:
        if name not in cur:
            failures.append(f"scenario {name!r} present in baseline but "
                            "missing from the current payload")
    for name, r in cur.items():
        dev = r.get("max_param_dev")
        if dev is None or not dev < dev_bound:
            failures.append(f"{name}: max_param_dev={dev!r} (bound "
                            f"{dev_bound:g}) — loop/vmap parity broke")
        traces = r.get("vmap_traces")
        if traces is not None and traces > 1:
            failures.append(f"{name}: vmap_traces={traces} — the fixed-K "
                            "fused graph retraced (contract: exactly one "
                            "compile per run)")
    mask_sum = current.get("secure_mask_sum_abs")
    if mask_sum != 0.0:
        failures.append(f"secure_mask_sum_abs={mask_sum!r} — secure-mask "
                        "cancellation must be bitwise exact (0.0)")

    # ---- warn-only trend gates: timings -------------------------------
    ratio, base_ratio = (current.get("straggler_over_sync_vmap"),
                         baseline.get("straggler_over_sync_vmap"))
    if ratio is not None and base_ratio:
        if ratio > timing_slack * base_ratio:
            _warn(f"straggler_over_sync_vmap {ratio:.2f} vs baseline "
                  f"{base_ratio:.2f} (> {timing_slack:g}x) — the fused "
                  "ring buffer may be paying host round-trips again")
    for name, r in cur.items():
        b = base.get(name)
        if not b:
            continue
        for key, worse_is in (("vmap_s_per_round", "higher"),
                              ("speedup", "lower")):
            c_v, b_v = r.get(key), b.get(key)
            if not (c_v and b_v):
                continue
            degraded = (c_v > timing_slack * b_v if worse_is == "higher"
                        else c_v * timing_slack < b_v)
            if degraded:
                _warn(f"{name}: {key} {c_v:.4g} vs baseline {b_v:.4g} "
                      f"(beyond {timing_slack:g}x slack)")

    if failures:
        for f in failures:
            print(f"FAIL: {f}", file=sys.stderr)
        return 1
    print(f"ci_gate: {len(cur)} scenarios pass "
          f"(dev<{dev_bound:g}, secure masks bitwise-cancelled, "
          "single-trace fixed-K); timing deltas warn-only")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("current", help="freshly produced bench payload")
    ap.add_argument("baseline", help="committed BENCH_*.json baseline")
    ap.add_argument("--dev-bound", type=float, default=DEV_BOUND)
    ap.add_argument("--timing-slack", type=float, default=TIMING_SLACK)
    a = ap.parse_args(argv)
    with open(a.current) as f:
        current = json.load(f)
    with open(a.baseline) as f:
        baseline = json.load(f)
    return gate(current, baseline, dev_bound=a.dev_bound,
                timing_slack=a.timing_slack)


if __name__ == "__main__":
    sys.exit(main())
