"""Non-IID scenario-suite sweep over the unified FederationEngine.

One synthetic federation, many regimes: for each named scenario
(partitioner x participation x staleness x heterogeneity x transforms)
the engine is stepped in BOTH execution modes and the sweep records
steady-state seconds/round, the loop-vs-vmap speedup, the max loop/vmap
parameter deviation (the correctness tripwire — since PR 4 that
includes the dp/topk/secure transform cells, which run IN-GRAPH on the
vmap path), the vmap trace count (the fixed-K retrace-free contract:
every scenario must compile its fused graph exactly once, including
``dropout-join``'s churning cohort sizes), and the final training loss.

Two headline measurements:
  * ``straggler_over_sync_vmap`` — the fused in-graph ring buffer
    (DESIGN.md §4): the straggler vmap round must sit within 1.5x of
    the synchronous vmap round at K=16;
  * ``secure_mask_sum_abs`` — the secure transform's pairwise masks
    summed over the client axis: BITWISE zero (exactly 0.0) by the
    dyadic-grid construction of ``core/transforms.py``; any non-zero
    value is a broken privacy invariant, hard-failed in CI.

    PYTHONPATH=src python -m benchmarks.bench_scenarios \\
        --out experiments/bench_scenarios.json

    # CI smoke: tiny federation, sync + straggler + one non-IID cell
    PYTHONPATH=src python -m benchmarks.bench_scenarios --quick

    # CI privacy smoke: add the in-graph transform cells
    PYTHONPATH=src python -m benchmarks.bench_scenarios --quick \\
        --transforms dp,topk

JSON layout: {"setup": {...}, "straggler_over_sync_vmap": float,
"secure_mask_sum_abs": float, "results": [{"scenario", "partition",
"loop_s_per_round", "vmap_s_per_round", "speedup", "max_param_dev",
"vmap_traces", "final_loss", ...}]}.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import NTM, FederatedConfig, ModelConfig, RoundConfig
from repro.core.ntm import prodlda
from repro.core.rounds import RoundEngine
from repro.core.transforms import pairwise_mask_stack
from repro.data.synthetic_lda import generate_lda_corpus
from repro.launch.simulate import build_clients


def scenario_grid(k: int, rounds_for_leave: int):
    """The scenario suite: name -> (partition spec, RoundConfig kwargs).

    Every scenario keeps K participants per round so the timing columns
    are comparable; the first two cells are the sync-vs-straggler
    headline pair.
    """
    join = (0,) * (k - 1) + (2,)             # one late joiner
    leave = (0,) * (k - 1) + (max(rounds_for_leave - 1, 1),)
    return {
        "sync": ("topic", {}),
        "straggler": ("topic", dict(straggler_prob=0.3, max_staleness=3,
                                    staleness_decay=0.5)),
        "straggler-heavy": ("topic", dict(straggler_prob=0.6,
                                          max_staleness=3,
                                          staleness_decay=0.25)),
        "dirichlet-noniid": ("dirichlet(0.3)", {}),
        "quantity-skew": ("quantity_skew(0.5)", {}),
        "hetero-epochs": ("topic", dict(local_epochs_by_client=(1, 2, 4))),
        "dropout-join": ("topic", dict(client_join_round=join,
                                       client_leave_round=leave)),
        "dp-transform": ("topic", dict(transforms=("dp",))),
        "topk-transform": ("topic", dict(transforms=("topk",))),
        "secure-transform": ("topic", dict(transforms=("secure",))),
        "dp-straggler": ("topic", dict(transforms=("dp",),
                                       straggler_prob=0.3, max_staleness=3,
                                       staleness_decay=0.5)),
    }


def secure_mask_cancellation(num_clients: int, seed: int = 0) -> float:
    """Max |sum over clients| of the secure transform's stacked pairwise
    masks — bitwise 0.0 by construction (``core/transforms.py``); any
    other value means the privacy invariant broke.  Probed on a small
    mixed-shape template; the property is shape-independent."""
    tmpl = {"w": jnp.zeros((13, 7), jnp.float32),
            "b": jnp.zeros((11,), jnp.float32)}
    stack = pairwise_mask_stack(jax.random.PRNGKey(seed), tmpl, num_clients)
    return max(float(np.abs(np.asarray(jnp.sum(leaf, axis=0))).max())
               for leaf in jax.tree_util.tree_leaves(stack))


def _max_dev(a, b) -> float:
    return max(float(np.max(np.abs(np.asarray(x) - np.asarray(y))))
               for x, y in zip(jax.tree_util.tree_leaves(a),
                               jax.tree_util.tree_leaves(b)))


def _time_rounds(eng: RoundEngine, *, warmup: int, rounds: int,
                 seed: int) -> float:
    """Steady-state MEDIAN seconds/round (first ``warmup`` rounds excluded
    — they pay tracing + compilation).  The median, not the mean: a
    single GC pause or scheduler preemption inside a cell would otherwise
    dominate the sync-vs-straggler headline ratio."""
    for r in range(warmup):
        eng.round(seed=seed * 100003 + r)
    jax.block_until_ready(eng.params)
    per_round = []
    for r in range(warmup, warmup + rounds):
        t0 = time.perf_counter()
        eng.round(seed=seed * 100003 + r)
        jax.block_until_ready(eng.params)
        per_round.append(time.perf_counter() - t0)
    return float(np.median(per_round))


def run(out_path="experiments/bench_scenarios.json", *, vocab=1000,
        topics=20, hidden=64, num_clients=16, docs_per_client=96, batch=64,
        lr=2e-3, seed=0, warmup=2, rounds=4, scenarios=None):
    cfg = ModelConfig(name="bench-scenarios", kind=NTM, vocab_size=vocab,
                      num_topics=topics, ntm_hidden=(hidden, hidden))
    syn = generate_lda_corpus(
        vocab_size=vocab, num_topics=topics, num_nodes=num_clients,
        shared_topics=max(topics // 5, 1), docs_per_node=docs_per_client,
        val_docs_per_node=8, seed=seed)
    loss_fn = lambda p, b: prodlda.elbo_loss(p, cfg, b, train=False)  # noqa: E731,E501
    loss_sum_fn = lambda p, b: prodlda.elbo_loss_sum(p, cfg, b, train=False)  # noqa: E731,E501
    init = prodlda.init_params(jax.random.PRNGKey(seed), cfg)
    fed = FederatedConfig(num_clients=num_clients, learning_rate=lr,
                          max_rounds=warmup + rounds, rel_tol=0.0)
    grid = scenario_grid(num_clients, warmup + rounds)
    if scenarios:
        unknown = sorted(set(scenarios) - set(grid))
        if unknown:
            raise ValueError(f"unknown scenario(s) {unknown}; known: "
                             f"{sorted(grid)} — a typo must not silently "
                             "shrink the sweep")
        grid = {k: v for k, v in grid.items() if k in scenarios}

    results = []
    for name, (partition, rc_kw) in grid.items():
        rc_kw = dict(rc_kw, sampling_seed=seed, partition=partition)
        tnames = rc_kw.get("transforms", ())
        if tnames:
            # clip/noise/frac sized for DELTA messages (magnitude ~
            # lr * |G|), not raw gradients
            sfed = FederatedConfig(
                num_clients=num_clients, learning_rate=lr,
                max_rounds=warmup + rounds, rel_tol=0.0,
                dp_noise_multiplier=0.3 if "dp" in tnames else 0.0,
                dp_clip_norm=0.05,
                compression_topk=0.25 if "topk" in tnames else 0.0)
        else:
            sfed = fed
        rc = RoundConfig(**rc_kw)
        clients = build_clients(syn, num_clients, partition, seed=seed)

        loop = RoundEngine(loss_fn, init, clients, sfed, rc,
                           batch_size=batch, exec_mode="loop",
                           loss_sum_fn=loss_sum_fn)
        t_loop = _time_rounds(loop, warmup=warmup, rounds=rounds, seed=seed)
        # since PR 4 every scenario — transforms included — rides the
        # fused vmap path; the loop run above is its reference
        vm = RoundEngine(loss_fn, init, clients, sfed, rc,
                         batch_size=batch, exec_mode="vmap",
                         loss_sum_fn=loss_sum_fn)
        t_vmap = _time_rounds(vm, warmup=warmup, rounds=rounds, seed=seed)
        rec = {"scenario": name, "partition": partition,
               "loop_s_per_round": t_loop,
               "vmap_s_per_round": t_vmap,
               "speedup": t_loop / max(t_vmap, 1e-12),
               "max_param_dev": _max_dev(loop.params, vm.params),
               # fixed-K contract: ONE compile per fused graph per run
               # (dropout-join's churning cohort sizes included)
               "vmap_traces": sum(vm.trace_counts.values()),
               "client_docs_min": min(c.num_docs for c in clients),
               "client_docs_max": max(c.num_docs for c in clients),
               "final_loss": loop.history[-1]["loss"]}
        results.append(rec)
        print(f"{name:18s} loop={t_loop * 1e3:8.1f}ms/round "
              f"vmap={t_vmap * 1e3:8.1f}ms/round "
              f"speedup={rec['speedup']:5.1f}x "
              f"dev={rec['max_param_dev']:.1e} "
              f"traces={rec['vmap_traces']}")

    by_name = {r["scenario"]: r for r in results}
    ratio = None
    if "sync" in by_name and "straggler" in by_name \
            and "vmap_s_per_round" in by_name["straggler"]:
        ratio = (by_name["straggler"]["vmap_s_per_round"]
                 / max(by_name["sync"]["vmap_s_per_round"], 1e-12))
        print(f"fused straggler ring buffer: {ratio:.2f}x the synchronous "
              f"vmap round (acceptance <= 1.5x at K=16)")

    # privacy invariant probe: the secure masks must sum to BITWISE zero
    # over the client axis at this federation's K (and a couple more;
    # clipped to the transform's 1024-client population cap)
    probe_ks = {k for k in (2, 3, num_clients, 2 * num_clients)
                if k <= 1024}
    mask_sum = max(secure_mask_cancellation(k, seed=seed)
                   for k in sorted(probe_ks))
    print(f"secure-mask cancellation: max |sum_l mask_l| = {mask_sum!r} "
          f"(must be exactly 0.0)")

    payload = {"setup": {"vocab": vocab, "topics": topics, "hidden": hidden,
                         "num_clients": num_clients,
                         "docs_per_client": docs_per_client, "batch": batch,
                         "lr": lr, "seed": seed, "warmup_rounds": warmup,
                         "timed_rounds": rounds,
                         "backend": jax.default_backend()},
               "straggler_over_sync_vmap": ratio,
               "secure_mask_sum_abs": mask_sum,
               "results": results}
    if out_path:
        os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
        with open(out_path, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"wrote {out_path} ({len(results)} scenarios)")
    return payload


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="experiments/bench_scenarios.json")
    ap.add_argument("--vocab", type=int, default=1000)
    ap.add_argument("--topics", type=int, default=20)
    ap.add_argument("--hidden", type=int, default=64)
    ap.add_argument("--num-clients", type=int, default=16)
    ap.add_argument("--docs-per-client", type=int, default=96)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--rounds", type=int, default=4,
                    help="timed steady-state rounds per scenario")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--scenarios", default="",
                    help="comma list to restrict the scenario grid")
    ap.add_argument("--transforms", default="",
                    help="comma list of transform names (dp, topk, "
                         "secure): adds the matching '<name>-transform' "
                         "cells to the selected scenario set — the CI "
                         "privacy-smoke entry point")
    ap.add_argument("--quick", action="store_true",
                    help="tiny federation, sync+straggler+one non-IID "
                         "cell — CI smoke for the fused ring buffer")
    a = ap.parse_args(argv)
    wanted = tuple(s for s in a.scenarios.split(",") if s) or None
    extra = tuple(f"{t.strip()}-transform"
                  for t in a.transforms.split(",") if t.strip())
    if a.quick:
        base = wanted or ("sync", "straggler", "dirichlet-noniid")
        return run(a.out, vocab=200, topics=5, hidden=32, num_clients=4,
                   docs_per_client=40, batch=16, rounds=2, seed=a.seed,
                   scenarios=tuple(base) + extra)
    if extra and wanted is not None:
        wanted = wanted + extra
    # (no --scenarios: wanted stays None = the FULL grid, which already
    # contains every *-transform cell — --transforms must never shrink it)
    return run(a.out, vocab=a.vocab, topics=a.topics, hidden=a.hidden,
               num_clients=a.num_clients,
               docs_per_client=a.docs_per_client, batch=a.batch,
               rounds=a.rounds, seed=a.seed, scenarios=wanted)


if __name__ == "__main__":
    main()
