"""Non-IID scenario-suite sweep over the unified FederationEngine.

One synthetic federation, many regimes: the cells are the NAMED registry
scenarios of ``repro.api.registry`` (``BENCH_SCENARIOS``), rebased onto
a bench-sized ``FederationSpec`` — there is no bench-local engine
wiring, so the sweep, the CLI and the CI gate can never drift apart.
For each scenario the spec is compiled through
``Federation.from_spec`` in BOTH execution modes and the sweep records
steady-state seconds/round, the loop-vs-vmap speedup, the max loop/vmap
parameter deviation (the correctness tripwire — the dp/topk/secure
transform cells run IN-GRAPH on the vmap path), the vmap trace count
(the fixed-K retrace-free contract: every scenario must compile its
fused graph exactly once, including ``dropout-join``'s churning cohort
sizes), and the final training loss.

Two headline measurements:
  * ``straggler_over_sync_vmap`` — the fused in-graph ring buffer
    (DESIGN.md §4): the straggler vmap round must sit within 1.5x of
    the synchronous vmap round at K=16;
  * ``secure_mask_sum_abs`` — the secure transform's pairwise masks
    summed over the client axis: BITWISE zero (exactly 0.0) by the
    dyadic-grid construction of ``core/transforms.py``; any non-zero
    value is a broken privacy invariant, hard-failed in CI.

    PYTHONPATH=src python -m benchmarks.bench_scenarios \\
        --out experiments/bench_scenarios.json

    # CI smoke: tiny federation, sync + straggler + one non-IID cell
    PYTHONPATH=src python -m benchmarks.bench_scenarios --quick

    # CI privacy smoke: add the in-graph transform cells
    PYTHONPATH=src python -m benchmarks.bench_scenarios --quick \\
        --transforms dp,topk

Cells whose spec pins ``execution.kernel_backend = "pallas"`` (the
``pallas-*`` registry scenarios) run the aggregation hot path through
the Pallas kernels (``kernels/fed_aggregate.py``; interpret mode on
CPU).  For those the sweep adds a THIRD run — the same vmap spec with
the XLA reference backend — and records ``backend_param_dev`` /
``backend_loss_dev``, the direct pallas-vs-xla parity numbers the CI
gate hard-fails on.  ``secure_mask_sum_abs_pallas`` re-probes the
mask-cancellation invariant with the client-axis sum computed INSIDE
the Pallas combine kernel (block-tiled accumulation order) — also
bitwise 0.0 by the dyadic-grid construction.

Cells whose spec sets ``execution.mesh`` (the ``mesh-*`` registry
scenarios) run the SAME fused graphs with the stacked ``(K, ...)``
cohort, the ``(L, ...)`` transform state and the straggler ring
row-sharded over a ``("data",)``-axis device mesh.  For those the
third run is instead the SAME spec unsharded (``execution.mesh =
None``, same kernel backend) — ``backend_param_dev`` /
``backend_loss_dev`` become the sharded-vs-unsharded parity numbers
(the mesh branch takes precedence over the pallas branch; pallas
backend parity is already covered by the ``pallas-*`` cells) and
``shard_over_single_vmap`` records the unsharded/sharded wall-clock
ratio.  Mesh cells need mesh-size-many visible devices: when the host
has fewer the cell is KEPT in the payload as a ``skipped`` record with
the reason (so the gate's strict scenario membership still holds) and
no numbers.  ``secure_mask_sum_abs_mesh`` re-probes the
mask-cancellation invariant through the SHARDED combine (per-device
partial sums + a cross-device ``psum``, both backends) — also bitwise
0.0: the dyadic grid makes every per-device partial an exact grid
integer, so the ≤N-term psum is exact (DESIGN.md).  Emitted only when
≥2 devices are visible.

JSON layout: {"setup": {..., "device_count"},
"straggler_over_sync_vmap": float, "secure_mask_sum_abs": float,
"secure_mask_sum_abs_pallas": float, ("secure_mask_sum_abs_mesh"
with >= 2 devices), "results": [{"scenario", "partition",
"kernel_backend", "device_count", "mesh_shape",
"loop_s_per_round", "vmap_s_per_round", "speedup", "max_param_dev",
"vmap_traces", "final_loss", ("backend_param_dev",
"backend_loss_dev" on pallas/mesh cells),
("shard_over_single_vmap" on mesh cells),
("skipped" on mesh cells the host cannot run), ...}]}.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import (BENCH_SCENARIOS, DataSpec, ExecutionSpec, Federation,
                       FederationSpec, ModelSpec, ScheduleSpec, build_corpus,
                       max_param_dev, scenario_spec, spec_replace)
from repro.core.engine import FederationEngine
from repro.core.transforms import pairwise_mask_stack

_max_dev = max_param_dev


def base_spec(*, vocab, topics, hidden, num_clients, docs_per_client,
              batch, lr, seed, rounds) -> FederationSpec:
    """The bench-sized base every scenario cell is rebased onto."""
    return FederationSpec(
        name="bench-scenarios",
        model=ModelSpec(vocab=vocab, topics=topics, hidden=hidden),
        data=DataSpec(num_clients=num_clients,
                      docs_per_node=docs_per_client, val_docs_per_node=8),
        schedule=ScheduleSpec(rounds=rounds),
        execution=ExecutionSpec(batch_size=batch, learning_rate=lr,
                                rel_tol=0.0, seed=seed))


def secure_mask_cancellation(num_clients: int, seed: int = 0,
                             backend: str = "xla",
                             mesh_data: int = 0) -> float:
    """Max |sum over clients| of the secure transform's stacked pairwise
    masks — bitwise 0.0 by construction (``core/transforms.py``); any
    other value means the privacy invariant broke.  Probed on a small
    mixed-shape template; the property is shape-independent.

    ``backend="pallas"`` computes the client-axis sum INSIDE the Pallas
    combine kernel (``fed_weighted_sum``, unit coefficients) — the
    block-tiled accumulation order must preserve the cancellation too,
    which the dyadic grid guarantees for ANY summation order.

    ``mesh_data > 0`` computes the sum through the SHARDED combine
    (``num_clients`` must divide it evenly): each device reduces its
    row shard to a partial sum, then a cross-device ``psum`` combines
    the partials.  Every per-device partial is an exact dyadic-grid
    integer, so the ≤N-term psum is exact too — the cancellation must
    stay bitwise under sharding, for either kernel backend."""
    tmpl = {"w": jnp.zeros((13, 7), jnp.float32),
            "b": jnp.zeros((11,), jnp.float32)}
    stack = pairwise_mask_stack(jax.random.PRNGKey(seed), tmpl, num_clients)
    mesh = None
    if mesh_data:
        from repro.parallel import sharding
        if num_clients % mesh_data:
            raise ValueError(f"mesh probe needs num_clients divisible by "
                             f"mesh_data, got {num_clients} % {mesh_data}")
        mesh = sharding.fed_mesh(mesh_data)
    if backend == "pallas" or mesh is not None:
        from repro.kernels import ops as kops
        total = kops.fed_weighted_sum(
            stack, jnp.ones((num_clients,), jnp.float32), backend=backend,
            mesh=mesh)
    else:
        total = jax.tree_util.tree_map(lambda l: jnp.sum(l, axis=0), stack)
    return max(float(np.abs(np.asarray(leaf)).max())
               for leaf in jax.tree_util.tree_leaves(total))


def _time_rounds(eng: FederationEngine, *, warmup: int, rounds: int,
                 seed: int) -> float:
    """Steady-state MEDIAN seconds/round (first ``warmup`` rounds excluded
    — they pay tracing + compilation).  The median, not the mean: a
    single GC pause or scheduler preemption inside a cell would otherwise
    dominate the sync-vs-straggler headline ratio."""
    for r in range(warmup):
        eng.round(seed=seed * 100003 + r)
    jax.block_until_ready(eng.params)
    per_round = []
    for r in range(warmup, warmup + rounds):
        t0 = time.perf_counter()
        eng.round(seed=seed * 100003 + r)
        jax.block_until_ready(eng.params)
        per_round.append(time.perf_counter() - t0)
    return float(np.median(per_round))


def run(out_path="experiments/bench_scenarios.json", *, vocab=1000,
        topics=20, hidden=64, num_clients=16, docs_per_client=96, batch=64,
        lr=2e-3, seed=0, warmup=2, rounds=4, scenarios=None):
    base = base_spec(vocab=vocab, topics=topics, hidden=hidden,
                     num_clients=num_clients,
                     docs_per_client=docs_per_client, batch=batch, lr=lr,
                     seed=seed, rounds=warmup + rounds)
    syn = build_corpus(base)
    names = BENCH_SCENARIOS
    if scenarios:
        unknown = sorted(set(scenarios) - set(BENCH_SCENARIOS))
        if unknown:
            raise ValueError(f"unknown scenario(s) {unknown}; known: "
                             f"{sorted(BENCH_SCENARIOS)} — a typo must "
                             "not silently shrink the sweep")
        names = tuple(n for n in BENCH_SCENARIOS if n in scenarios)

    dev_count = jax.device_count()
    results = []
    for name in names:
        spec = scenario_spec(name, base)
        mesh_n = (spec.execution.mesh.data
                  if spec.execution.mesh is not None else 0)
        mesh_shape = {"data": mesh_n} if mesh_n else None
        if mesh_n > dev_count:
            # kept in the payload (strict scenario membership in the CI
            # gate) but carrying no numbers — the reason is recorded so
            # the skip is auditable, never silent
            reason = (f"needs {mesh_n} devices, {dev_count} visible — "
                      "export XLA_FLAGS=--xla_force_host_platform_"
                      f"device_count={mesh_n} before importing jax")
            results.append({"scenario": name,
                            "partition": spec.data.partition.to_string(),
                            "kernel_backend": spec.execution.kernel_backend,
                            "device_count": dev_count,
                            "mesh_shape": mesh_shape,
                            "skipped": reason})
            print(f"{name:18s} SKIPPED: {reason}")
            continue
        loop = Federation.from_spec(
            spec_replace(spec, {"execution.exec_mode": "loop"}),
            corpus=syn).engine
        t_loop = _time_rounds(loop, warmup=warmup, rounds=rounds, seed=seed)
        # every scenario — transforms included — rides the fused vmap
        # path; the loop run above is its reference
        vm = Federation.from_spec(
            spec_replace(spec, {"execution.exec_mode": "vmap"}),
            corpus=syn).engine
        t_vmap = _time_rounds(vm, warmup=warmup, rounds=rounds, seed=seed)
        clients = loop.clients
        rec = {"scenario": name,
               "partition": spec.data.partition.to_string(),
               "kernel_backend": spec.execution.kernel_backend,
               "device_count": dev_count,
               "mesh_shape": mesh_shape,
               "loop_s_per_round": t_loop,
               "vmap_s_per_round": t_vmap,
               "speedup": t_loop / max(t_vmap, 1e-12),
               "max_param_dev": _max_dev(loop.params, vm.params),
               # fixed-K contract: ONE compile per fused graph per run
               # (dropout-join's churning cohort sizes included)
               "vmap_traces": sum(vm.trace_counts.values()),
               "client_docs_min": min(c.num_docs for c in clients),
               "client_docs_max": max(c.num_docs for c in clients),
               "final_loss": loop.history[-1]["loss"]}
        if mesh_n:
            # third run: the SAME spec unsharded (same kernel backend)
            # — backend_param_dev/backend_loss_dev isolate the mesh
            # sharding itself, and the wall-clock ratio is the
            # shard_over_single_vmap headline (the pallas branch below
            # yields: pallas backend parity is the pallas-* cells' job)
            vu = Federation.from_spec(
                spec_replace(spec, {"execution.exec_mode": "vmap",
                                    "execution.mesh": None}),
                corpus=syn).engine
            t_unsharded = _time_rounds(vu, warmup=warmup, rounds=rounds,
                                       seed=seed)
            rec["backend_param_dev"] = _max_dev(vu.params, vm.params)
            rec["backend_loss_dev"] = abs(vu.history[-1]["loss"]
                                          - vm.history[-1]["loss"])
            rec["shard_over_single_vmap"] = (t_unsharded
                                             / max(t_vmap, 1e-12))
        elif spec.execution.kernel_backend == "pallas":
            # third run: same vmap spec on the XLA reference backend —
            # the DIRECT pallas-vs-xla parity numbers (the loop run
            # above differs by exec path as well as backend)
            vx = Federation.from_spec(
                spec_replace(spec, {"execution.exec_mode": "vmap",
                                    "execution.kernel_backend": "xla"}),
                corpus=syn).engine
            _time_rounds(vx, warmup=warmup, rounds=rounds, seed=seed)
            rec["backend_param_dev"] = _max_dev(vx.params, vm.params)
            rec["backend_loss_dev"] = abs(vx.history[-1]["loss"]
                                          - vm.history[-1]["loss"])
        results.append(rec)
        extra = ""
        if "backend_param_dev" in rec:
            tag = ("sharded-vs-unsharded" if mesh_n else "xla-vs-pallas")
            extra = f" {tag}={rec['backend_param_dev']:.1e}"
        if "shard_over_single_vmap" in rec:
            extra += f" shardx={rec['shard_over_single_vmap']:4.2f}"
        print(f"{name:18s} loop={t_loop * 1e3:8.1f}ms/round "
              f"vmap={t_vmap * 1e3:8.1f}ms/round "
              f"speedup={rec['speedup']:5.1f}x "
              f"dev={rec['max_param_dev']:.1e} "
              f"traces={rec['vmap_traces']}{extra}")

    by_name = {r["scenario"]: r for r in results}
    ratio = None
    if "sync" in by_name and "straggler" in by_name \
            and "vmap_s_per_round" in by_name["straggler"]:
        ratio = (by_name["straggler"]["vmap_s_per_round"]
                 / max(by_name["sync"]["vmap_s_per_round"], 1e-12))
        print(f"fused straggler ring buffer: {ratio:.2f}x the synchronous "
              f"vmap round (acceptance <= 1.5x at K=16)")

    # privacy invariant probe: the secure masks must sum to BITWISE zero
    # over the client axis at this federation's K (and a couple more;
    # clipped to the transform's 1024-client population cap)
    probe_ks = {k for k in (2, 3, num_clients, 2 * num_clients)
                if k <= 1024}
    mask_sum = max(secure_mask_cancellation(k, seed=seed)
                   for k in sorted(probe_ks))
    print(f"secure-mask cancellation: max |sum_l mask_l| = {mask_sum!r} "
          f"(must be exactly 0.0)")
    # ... and the same sum computed INSIDE the Pallas combine kernel:
    # the block-tiled accumulation order must not break the dyadic-grid
    # cancellation either
    mask_sum_pl = max(secure_mask_cancellation(k, seed=seed,
                                               backend="pallas")
                      for k in sorted(probe_ks))
    print(f"secure-mask cancellation (pallas combine): "
          f"{mask_sum_pl!r} (must be exactly 0.0)")
    # ... and through the SHARDED combine: per-device partial sums +
    # cross-device psum, both backends, on the largest power-of-two
    # device mesh the host can build (probe Ks are mesh multiples so
    # the rows shard evenly) — only meaningful with >= 2 devices
    mask_sum_mesh = None
    if dev_count >= 2:
        mesh_d = 1 << (dev_count.bit_length() - 1)
        mask_sum_mesh = max(
            secure_mask_cancellation(mesh_d * m, seed=seed, backend=bk,
                                     mesh_data=mesh_d)
            for bk in ("xla", "pallas") for m in (1, 2, 3))
        print(f"secure-mask cancellation (sharded combine, data={mesh_d}): "
              f"{mask_sum_mesh!r} (must be exactly 0.0)")

    payload = {"setup": {"vocab": vocab, "topics": topics, "hidden": hidden,
                         "num_clients": num_clients,
                         "docs_per_client": docs_per_client, "batch": batch,
                         "lr": lr, "seed": seed, "warmup_rounds": warmup,
                         "timed_rounds": rounds,
                         "backend": jax.default_backend(),
                         "device_count": dev_count},
               "straggler_over_sync_vmap": ratio,
               "secure_mask_sum_abs": mask_sum,
               "secure_mask_sum_abs_pallas": mask_sum_pl,
               "results": results}
    if mask_sum_mesh is not None:
        payload["secure_mask_sum_abs_mesh"] = mask_sum_mesh
    if out_path:
        os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
        with open(out_path, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"wrote {out_path} ({len(results)} scenarios)")
    return payload


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="experiments/bench_scenarios.json")
    ap.add_argument("--vocab", type=int, default=1000)
    ap.add_argument("--topics", type=int, default=20)
    ap.add_argument("--hidden", type=int, default=64)
    ap.add_argument("--num-clients", type=int, default=16)
    ap.add_argument("--docs-per-client", type=int, default=96)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--rounds", type=int, default=4,
                    help="timed steady-state rounds per scenario")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--scenarios", default="",
                    help="comma list to restrict the scenario grid")
    ap.add_argument("--transforms", default="",
                    help="comma list of transform names (dp, topk, "
                         "secure): adds the matching '<name>-transform' "
                         "cells to the selected scenario set — the CI "
                         "privacy-smoke entry point")
    ap.add_argument("--quick", action="store_true",
                    help="tiny federation, sync+straggler+one non-IID "
                         "cell — CI smoke for the fused ring buffer")
    a = ap.parse_args(argv)
    wanted = tuple(s for s in a.scenarios.split(",") if s) or None
    extra = tuple(f"{t.strip()}-transform"
                  for t in a.transforms.split(",") if t.strip())
    if a.quick:
        base = wanted or ("sync", "straggler", "dirichlet-noniid")
        return run(a.out, vocab=200, topics=5, hidden=32, num_clients=4,
                   docs_per_client=40, batch=16, rounds=2, seed=a.seed,
                   scenarios=tuple(base) + extra)
    if extra and wanted is not None:
        wanted = wanted + extra
    # (no --scenarios: wanted stays None = the FULL grid, which already
    # contains every *-transform cell — --transforms must never shrink it)
    return run(a.out, vocab=a.vocab, topics=a.topics, hidden=a.hidden,
               num_clients=a.num_clients,
               docs_per_client=a.docs_per_client, batch=a.batch,
               rounds=a.rounds, seed=a.seed, scenarios=wanted)


if __name__ == "__main__":
    main()
