"""Roofline table builder: experiments/dryrun/*.json -> markdown table.

Reads every dry-run report and emits the EXPERIMENTS.md §Roofline table:
per (arch x shape x mesh) the three roofline terms, the dominant
bottleneck, MODEL_FLOPS/HLO_FLOPs, and the per-device memory proof.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import List


def load_reports(path="experiments/dryrun") -> List[dict]:
    out = []
    for f in sorted(glob.glob(os.path.join(path, "*.json"))):
        with open(f) as fh:
            out.append(json.load(fh))
    return out


SHAPE_ORDER = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2,
               "long_500k": 3}


def table(reports: List[dict], *, mesh=None) -> str:
    rows = []
    header = ("| arch | shape | mesh | compute (ms) | memory (ms) | "
              "collective (ms) | bound | useful-FLOPs | args+temp GiB/dev |")
    sep = "|" + "---|" * 9
    rows.append(header)
    rows.append(sep)
    reports = [r for r in reports if mesh is None or r["mesh"] == mesh]
    reports.sort(key=lambda r: (r["arch"], SHAPE_ORDER.get(r["shape"], 9),
                                r["mesh"]))
    for r in reports:
        mem = r.get("memory_per_device", {})
        gib = (mem.get("argument_size_in_bytes", 0)
               + mem.get("temp_size_in_bytes", 0)) / 2 ** 30
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['compute_s']*1e3:.2f} | {r['memory_s']*1e3:.2f} "
            f"| {r['collective_s']*1e3:.2f} | {r['bottleneck']} "
            f"| {r['useful_flops_ratio']:.3f} | {gib:.2f} |")
    return "\n".join(rows)


def summarize(reports: List[dict]) -> str:
    lines = []
    from collections import Counter
    c = Counter(r["bottleneck"] for r in reports)
    lines.append(f"pairs: {len(reports)}; bottleneck mix: {dict(c)}")
    worst = sorted(reports, key=lambda r: -max(
        r["compute_s"], r["memory_s"], r["collective_s"]))[:3]
    for r in worst:
        lines.append(f"  worst roofline: {r['arch']} x {r['shape']} "
                     f"({r['mesh']}): {r['bottleneck']} "
                     f"{max(r['compute_s'], r['memory_s'], r['collective_s'])*1e3:.1f}ms")
    return "\n".join(lines)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--path", default="experiments/dryrun")
    ap.add_argument("--mesh", default=None)
    args = ap.parse_args(argv)
    reports = load_reports(args.path)
    if not reports:
        print("no dry-run reports found; run python -m repro.launch.dryrun")
        return
    print(table(reports, mesh=args.mesh))
    print()
    print(summarize(reports))


if __name__ == "__main__":
    main()
