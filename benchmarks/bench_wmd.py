"""Paper Fig. 4 reproduction: AMWMD between node-specific and federated
models on real-style data (paper §4.2).

S2ORC is not redistributable offline (data gate, DESIGN.md §11); we build a
synthetic 5-"discipline" corpus with the same structure the paper relies
on: each client's documents concentrate on discipline-specific topics plus
a shared base, and word embeddings carry topic locality.  gFedNTM with
CombinedTM (the paper's §4.2 configuration, via the Algorithm-1 trainer)
is compared against the five non-collaborative CTMs using AMWMD (Eq. 7):
the federated model should describe EVERY node's topics better than any
other single node's model does — Fig. 4's qualitative claim.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import NTM, FederatedConfig, ModelConfig
from repro.core.ntm import prodlda
from repro.core.protocol import (ClientState, FederatedTrainer,
                                 train_centralized)
from repro.data.synthetic_lda import (fake_contextual_embeddings,
                                      generate_lda_corpus)
from repro.metrics import amwmd
from repro.optim import adam

DISCIPLINES = ["CS", "Econ", "Sociology", "Philosophy", "PoliSci"]


def run(out_path="experiments/bench_wmd.json", *, vocab=500, topics=20,
        docs=600, steps=250, k_fed=(10, 25), quick=False, seed=0):
    if quick:
        docs, steps, k_fed = 250, 150, (12,)
        topics = 15
    num_nodes = len(DISCIPLINES)
    syn = generate_lda_corpus(
        vocab_size=vocab, num_topics=topics, num_nodes=num_nodes,
        shared_topics=max(topics // 4, 1), eta=0.02,
        docs_per_node=docs, val_docs_per_node=50, seed=seed)
    ctx_dim = 64
    # topic-local word embeddings: project each word's topic profile
    rng = np.random.default_rng(seed)
    topic_axes = rng.standard_normal((topics, 16)).astype(np.float32)
    word_emb = (syn.beta.T / syn.beta.T.sum(1, keepdims=True)) @ topic_axes
    word_emb += 0.05 * rng.standard_normal(word_emb.shape).astype(np.float32)

    def make_cfg(k):
        return ModelConfig(name=f"ctm-{k}", kind=NTM, vocab_size=vocab,
                           num_topics=k, ntm_hidden=(100, 100),
                           contextual_dim=ctx_dim)

    # non-collaborative CTM per node
    node_models = []
    cfg_node = make_cfg(max(topics // num_nodes + 2, 4))
    for l, bows in enumerate(syn.node_bows):
        ctx = fake_contextual_embeddings(bows, ctx_dim, seed=1)
        loss = lambda p, b: prodlda.elbo_loss(p, cfg_node, b)  # noqa: E731
        init = prodlda.init_params(jax.random.PRNGKey(seed + l), cfg_node)
        node_models.append(train_centralized(
            loss, init, {"bow": bows, "contextual": ctx},
            optimizer=adam(2e-3), batch_size=64, steps=steps,
            seed=seed + l))

    # federated CTM via Algorithm 1 (the gFedNTM run)
    fed_models = {}
    for k in k_fed:
        cfg_fed = make_cfg(k)
        loss = lambda p, b: prodlda.elbo_loss(p, cfg_fed, b)  # noqa: E731
        init = prodlda.init_params(jax.random.PRNGKey(seed + 100), cfg_fed)
        clients = [
            ClientState(
                data={"bow": b,
                      "contextual": fake_contextual_embeddings(b, ctx_dim,
                                                               seed=1)},
                num_docs=len(b))
            for b in syn.node_bows]
        tr = FederatedTrainer(
            loss, init, clients,
            FederatedConfig(num_clients=num_nodes, learning_rate=2e-3,
                            max_rounds=steps, rel_tol=0.0),
            optimizer=adam(2e-3), batch_size=64)
        fed_models[k] = tr.fit(seed=seed)

    # AMWMD of each evaluated model against each node's own topics
    results = {"nodes": DISCIPLINES, "amwmd": {}}
    node_betas = [np.asarray(prodlda.get_topics(p)) for p in node_models]
    evals = {f"node:{DISCIPLINES[j]}": node_betas[j]
             for j in range(num_nodes)}
    for k, p in fed_models.items():
        evals[f"federated:K={k}"] = np.asarray(prodlda.get_topics(p))

    t0 = time.time()
    for name, beta_eval in evals.items():
        row = []
        for l in range(num_nodes):
            if name == f"node:{DISCIPLINES[l]}":
                row.append(0.0)       # AMWMD to itself is 0 by definition
                continue
            row.append(amwmd(node_betas[l], beta_eval, word_emb, top_n=8))
        results["amwmd"][name] = row
        print(f"{name:18s} " + " ".join(f"{v:7.3f}" for v in row)
              + f"   avg={np.mean(row):.3f}")
    results["wall_s"] = time.time() - t0

    # Fig. 4 claim: the federated model covers every node better on
    # average than any other single node's model
    fed_keys = [k for k in results["amwmd"] if k.startswith("federated")]
    node_keys = [k for k in results["amwmd"] if k.startswith("node")]
    best_fed = min(float(np.mean(results["amwmd"][k])) for k in fed_keys)
    cross_node = []
    for k in node_keys:
        row = results["amwmd"][k]
        cross = [v for v in row if v > 0.0]
        cross_node.append(float(np.mean(cross)))
    results["fig4_claim_holds"] = bool(best_fed < min(cross_node))
    print(f"Fig.4 claim (federated covers all nodes better): "
          f"{results['fig4_claim_holds']} "
          f"(fed avg {best_fed:.3f} vs best cross-node "
          f"{min(cross_node):.3f})")

    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2)
    return results


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--steps", type=int, default=250)
    args = ap.parse_args(argv)
    run(steps=args.steps, quick=args.quick)


if __name__ == "__main__":
    main()
