"""Loop-vs-vmap cohort execution sweep (the vectorized engine's headline).

Each (K clients-per-round, E local epochs) cell is ONE declarative
``FederationSpec`` (``repro.api``) run through ``Federation.from_spec``
twice over the same synthetic federation: ``exec_mode="loop"`` — one
jitted grad dispatch per client per epoch, host round-trips between
them — and ``exec_mode="vmap"`` — all K local-update loops, the Eq. (2)
combine and the server optimizer fused into one jitted graph
(DESIGN.md §4).  Both modes retrace the same parameter trajectory
(property suite in tests/test_vmap_equivalence.py); this benchmark
records what that costs: steady-state seconds per round (post-warm-up,
so compile time is excluded) and the loop/vmap speedup per cell.

    PYTHONPATH=src python -m benchmarks.bench_clients \\
        --out experiments/bench_clients.json

    # CI smoke: one tiny cell, exercises the whole vmap path in seconds
    PYTHONPATH=src python -m benchmarks.bench_clients --quick

JSON layout: {"grid": {...}, "setup": {...}, "results": [{"clients_per_round",
"local_epochs", "loop_s_per_round", "vmap_s_per_round", "speedup",
"max_param_dev", ...}]}.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax

from repro.api import (DataSpec, ExecutionSpec, Federation, FederationSpec,
                       ModelSpec, ScheduleSpec, build_corpus, max_param_dev,
                       spec_replace)
from repro.core.engine import FederationEngine

K_SWEEP = (4, 16, 64)
E_SWEEP = (1, 4)

_max_dev = max_param_dev


def _time_rounds(eng: FederationEngine, *, warmup: int, rounds: int,
                 seed: int) -> float:
    """Steady-state mean seconds/round (first ``warmup`` rounds excluded —
    they pay tracing + compilation)."""
    for r in range(warmup):
        eng.round(seed=seed * 100003 + r)
    jax.block_until_ready(eng.params)
    t0 = time.perf_counter()
    for r in range(warmup, warmup + rounds):
        eng.round(seed=seed * 100003 + r)
    jax.block_until_ready(eng.params)
    return (time.perf_counter() - t0) / rounds


def run(out_path="experiments/bench_clients.json", *, vocab=1000, topics=20,
        hidden=64, docs_per_client=96, batch=64, lr=2e-3, seed=0,
        warmup=1, rounds=3, k_sweep=K_SWEEP, e_sweep=E_SWEEP):
    num_clients = max(k_sweep)
    base = FederationSpec(
        name="bench-clients",
        model=ModelSpec(vocab=vocab, topics=topics, hidden=hidden),
        data=DataSpec(num_clients=num_clients,
                      docs_per_node=docs_per_client, val_docs_per_node=8),
        schedule=ScheduleSpec(rounds=warmup + rounds),
        execution=ExecutionSpec(batch_size=batch, learning_rate=lr,
                                rel_tol=0.0, seed=seed))
    syn = build_corpus(base)

    results = []
    for k in k_sweep:
        for e in e_sweep:
            spec = spec_replace(base, {"schedule.clients_per_round": k,
                                       "schedule.local_epochs": e})
            loop = Federation.from_spec(
                spec_replace(spec, {"execution.exec_mode": "loop"}),
                corpus=syn).engine
            vm = Federation.from_spec(
                spec_replace(spec, {"execution.exec_mode": "vmap"}),
                corpus=syn).engine
            t_loop = _time_rounds(loop, warmup=warmup, rounds=rounds,
                                  seed=seed)
            t_vmap = _time_rounds(vm, warmup=warmup, rounds=rounds,
                                  seed=seed)
            dev = _max_dev(loop.params, vm.params)
            rec = {"clients_per_round": k, "local_epochs": e,
                   "loop_s_per_round": t_loop,
                   "vmap_s_per_round": t_vmap,
                   "speedup": t_loop / max(t_vmap, 1e-12),
                   "max_param_dev": dev,
                   "final_loss_loop": loop.history[-1]["loss"],
                   "final_loss_vmap": vm.history[-1]["loss"]}
            results.append(rec)
            print(f"K={k:3d} E={e}: loop={t_loop*1e3:8.1f}ms/round "
                  f"vmap={t_vmap*1e3:8.1f}ms/round "
                  f"speedup={rec['speedup']:5.1f}x dev={dev:.1e}")

    payload = {"grid": {"clients_per_round": list(k_sweep),
                        "local_epochs": list(e_sweep)},
               "setup": {"vocab": vocab, "topics": topics, "hidden": hidden,
                         "num_clients": num_clients,
                         "docs_per_client": docs_per_client, "batch": batch,
                         "lr": lr, "seed": seed, "warmup_rounds": warmup,
                         "timed_rounds": rounds,
                         "backend": jax.default_backend()},
               "results": results}
    if out_path:
        os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
        with open(out_path, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"wrote {out_path} ({len(results)} cells)")
    return payload


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="experiments/bench_clients.json")
    ap.add_argument("--vocab", type=int, default=1000)
    ap.add_argument("--topics", type=int, default=20)
    ap.add_argument("--hidden", type=int, default=64)
    ap.add_argument("--docs-per-client", type=int, default=96)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--rounds", type=int, default=3,
                    help="timed steady-state rounds per cell")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--quick", action="store_true",
                    help="one tiny (K=4, E=1) cell — CI smoke for the "
                         "vmap path")
    a = ap.parse_args(argv)
    if a.quick:
        return run(a.out, vocab=200, topics=5, hidden=32,
                   docs_per_client=40, batch=16, rounds=2,
                   k_sweep=(4,), e_sweep=(1,), seed=a.seed)
    return run(a.out, vocab=a.vocab, topics=a.topics, hidden=a.hidden,
               docs_per_client=a.docs_per_client, batch=a.batch,
               rounds=a.rounds, seed=a.seed)


if __name__ == "__main__":
    main()
