"""Docs reference checker — every intra-repo link must resolve.

Stdlib-only (runnable before PYTHONPATH is set, like the trend gate).
Scans ``README.md``, ``DESIGN.md`` and ``docs/*.md`` for:

* markdown links ``[text](target)`` — external (``http``/``mailto``)
  and pure-anchor targets are skipped; everything else, fragment
  stripped, must exist relative to the linking file's directory (or the
  repo root as a fallback for root-style paths);
* backtick file references — `` `path/to/file.py` `` (also ``.md`` /
  ``.json`` / ``.yml`` / ``.toml``), optionally suffixed
  ``:symbol`` or ``:lineno``.  Paths resolve against the roots ``.``,
  ``src`` and ``src/repro`` (docs refer to modules both ways); a bare
  filename (the repo-map-table style, `` `spec.py` `` inside an
  ``api/`` row) resolves through a repo-wide basename index.  A
  ``:symbol`` must occur as a word in the file, a ``:lineno`` must not
  exceed the file's length.  Glob-ish tokens (``docs/*.md``) are
  skipped — they name families, not files.

Exit 1 listing every dangling reference; CI runs this on every push
(and ``tests/test_docs_refs.py`` runs it under tier-1), so a rename
that strands the docs fails before review.

Usage:

    python -m benchmarks.check_docs          # from the repo root
    python benchmarks/check_docs.py --root /path/to/repo
"""
from __future__ import annotations

import argparse
import glob
import os
import re
import sys

DOC_GLOBS = ("README.md", "DESIGN.md", "docs/*.md")
ROOTS = (".", "src", "src/repro")

_MD_LINK = re.compile(r"\[[^\]\n]*\]\(([^)\s]+)\)")
_BACKTICK = re.compile(
    r"`([\w./-]+\.(?:py|md|json|yml|yaml|toml))"
    r"(?::([A-Za-z_][\w.]*|\d+))?`")


_SKIP_DIRS = {".git", "__pycache__", ".pytest_cache", "node_modules"}


def _basename_index(root: str):
    """basename -> first path, over the whole tree (bare-filename refs)."""
    index = {}
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = sorted(d for d in dirnames if d not in _SKIP_DIRS)
        for fn in sorted(filenames):
            index.setdefault(fn, os.path.join(dirpath, fn))
    return index


def _resolve(target: str, base_dir: str, root: str, index):
    """First existing candidate path for a doc reference, else None."""
    cands = [os.path.join(base_dir, target)]
    cands += [os.path.join(root, r, target) for r in ROOTS]
    for c in cands:
        if os.path.exists(c):
            return c
    if "/" not in target:
        return index.get(target)
    return None


def check_file(path: str, root: str, index) -> list:
    problems = []
    base_dir = os.path.dirname(path) or "."
    rel = os.path.relpath(path, root)
    text = open(path, encoding="utf-8").read()
    for lineno, line in enumerate(text.splitlines(), 1):
        for m in _MD_LINK.finditer(line):
            target = m.group(1)
            if target.startswith(("http://", "https://", "mailto:")) \
                    or target.startswith("#"):
                continue
            target = target.split("#", 1)[0]
            if not target:
                continue
            if _resolve(target, base_dir, root, index) is None:
                problems.append(f"{rel}:{lineno}: broken link "
                                f"({m.group(0)}) — {target!r} does not "
                                "exist")
        for m in _BACKTICK.finditer(line):
            target, suffix = m.group(1), m.group(2)
            if "*" in target:
                continue
            found = _resolve(target, base_dir, root, index)
            if found is None:
                problems.append(f"{rel}:{lineno}: backtick reference "
                                f"`{target}` resolves under none of "
                                f"{ROOTS}")
                continue
            if suffix is None:
                continue
            content = open(found, encoding="utf-8").read()
            if suffix.isdigit():
                if int(suffix) > content.count("\n") + 1:
                    problems.append(
                        f"{rel}:{lineno}: `{target}:{suffix}` points "
                        f"past the end of {found}")
            elif not re.search(
                    r"\b" + re.escape(suffix.split(".")[-1]) + r"\b",
                    content):
                problems.append(
                    f"{rel}:{lineno}: `{target}:{suffix}` — symbol "
                    f"{suffix!r} does not occur in {found}")
    return problems


def check_docs(root: str = ".") -> list:
    files = []
    for g in DOC_GLOBS:
        files += sorted(glob.glob(os.path.join(root, g)))
    if not files:
        return [f"no doc files matched {DOC_GLOBS} under {root!r} — "
                "the checker must check something"]
    index = _basename_index(root)
    problems = []
    for f in files:
        problems += check_file(f, root, index)
    if not problems:
        print(f"check_docs: {len(files)} doc files, all intra-repo "
              "references resolve")
    return problems


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--root", default=".",
                    help="repository root (default: cwd)")
    problems = check_docs(ap.parse_args(argv).root)
    for p in problems:
        print(f"FAIL: {p}", file=sys.stderr)
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main())
