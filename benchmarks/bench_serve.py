"""Buffered-async service benchmark — correctness anchor + concurrent
train/serve throughput (the ``serve_results`` payload block).

Three cells, gated by ``benchmarks/ci_gate.py`` against the committed
baseline (HARD on correctness, warn-only on timing — the repo-wide
two-tier policy):

* ``sync-equivalence`` — the DESIGN.md §6 anchor: a buffered-async
  service with ``M=K``, ``max_staleness=0`` and in-order arrivals must
  reproduce the synchronous FedAvg trajectory of the sync twin spec.
  ``final_param_dev`` hard-fails at the repo-wide 1e-5 bound.
* ``buffered-async`` — the FedBuff regime (M < L, held-back uploads,
  duplicate resubmissions): records aggregations, the rejection ledger
  (every reason must be a documented ``REJECT_REASONS`` member — an
  unnamed rejection path hard-fails), and observed staleness.
  ``uploads_per_s`` is the train-side throughput (warn-only trend).
* ``train-serve`` — the same service answering inference every other
  step while training: ``infer_latency_p50_s`` /
  ``infer_throughput_per_s`` are the serve-side cells (warn-only
  trend); zero recorded inference calls hard-fails (the measurement
  silently stopped).

Usage (what .github/workflows/ci.yml runs):

    PYTHONPATH=src python -m benchmarks.bench_serve --quick \\
        --out experiments/bench_serve_ci.json
    python -m benchmarks.ci_gate experiments/bench_serve_ci.json \\
        benchmarks/baselines/BENCH_scenarios_ci.json
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax

from repro.api import (DataSpec, ExecutionSpec, Federation, FederationSpec,
                       ModelSpec, ScheduleSpec, build_corpus,
                       max_param_dev, spec_replace)
from repro.serve import FederationService, run_traffic, sync_twin_spec


def base_async_spec(*, vocab, topics, hidden, num_clients, docs, batch,
                    rounds) -> FederationSpec:
    # lr below the tiny-config divergence point (the same sizing the
    # scenario tests use) — the anchor compares absolute param devs, so
    # both trajectories must stay numerically sane
    return spec_replace(
        FederationSpec(
            name="bench-serve",
            model=ModelSpec(vocab=vocab, topics=topics, hidden=hidden),
            data=DataSpec(num_clients=num_clients, docs_per_node=docs,
                          val_docs_per_node=8),
            schedule=ScheduleSpec(rounds=rounds),
            execution=ExecutionSpec(batch_size=batch,
                                    learning_rate=2e-4)),
        {"schedule.mode": "buffered_async",
         "schedule.max_staleness": 0,
         "execution.exec_mode": "loop"})


def equivalence_cell(spec, corpus, *, sweeps) -> dict:
    """M=K, staleness 0, in-order arrivals vs the sync twin trajectory."""
    twin = spec_replace(sync_twin_spec(spec), {"schedule.rounds": sweeps})
    fed = Federation.from_spec(twin, corpus=corpus)
    fed.run()
    svc = FederationService.from_spec(spec, corpus=corpus)
    L = spec.data.num_clients
    t0 = time.perf_counter()
    accepted = 0
    for _ in range(sweeps):
        for c in range(L):
            accepted += int(svc.upload(c)["accepted"])
    wall = time.perf_counter() - t0
    return {"cell": "sync-equivalence",
            "final_param_dev": max_param_dev(fed.engine.params,
                                             svc._live[1]),
            "aggregations": svc.agg_index, "version": svc.version,
            "uploads": sweeps * L, "accepted": accepted,
            "uploads_per_s": sweeps * L / wall}


def traffic_cell(name, spec, corpus, *, sweeps, infer_every,
                 infer_batch) -> dict:
    svc = FederationService.from_spec(spec, corpus=corpus)
    t0 = time.perf_counter()
    stats = run_traffic(svc, sweeps=sweeps, order_seed=1, hold_prob=0.25,
                        duplicate_prob=0.2, infer_every=infer_every,
                        infer_batch=infer_batch)
    stats.update(svc.shutdown())
    wall = time.perf_counter() - t0
    cell = {"cell": name, "uploads_per_s": stats["uploads"] / wall}
    cell.update({k: stats[k] for k in
                 ("uploads", "accepted", "aggregations", "version",
                  "rejections", "mean_staleness", "max_staleness_seen",
                  "infer_calls")})
    for k in ("infer_latency_p50_s", "infer_throughput_per_s"):
        if k in stats:
            cell[k] = stats[k]
    return cell


def run_bench(args) -> dict:
    size = dict(vocab=64, topics=4, hidden=16, num_clients=4, docs=40,
                batch=16, rounds=3) if args.quick else \
        dict(vocab=200, topics=8, hidden=32, num_clients=6, docs=120,
             batch=32, rounds=6)
    sweeps = 3 if args.quick else 6
    spec = base_async_spec(**size)
    corpus = build_corpus(sync_twin_spec(spec))
    fedbuff = spec_replace(spec, {"schedule.buffer_size": 2,
                                  "schedule.max_staleness": 2,
                                  "schedule.staleness_policy":
                                      "polynomial"})
    results = [
        equivalence_cell(spec, corpus, sweeps=sweeps),
        traffic_cell("buffered-async", fedbuff, corpus, sweeps=sweeps,
                     infer_every=0, infer_batch=0),
        traffic_cell("train-serve", fedbuff, corpus, sweeps=sweeps,
                     infer_every=2,
                     infer_batch=4 if args.quick else 16),
    ]
    for r in results:
        extra = (f" dev={r['final_param_dev']:.1e}"
                 if "final_param_dev" in r else
                 f" rejections={r.get('rejections', {})}")
        print(f"[{r['cell']}] aggs={r['aggregations']} "
              f"up/s={r['uploads_per_s']:.1f}{extra}")
    return {"setup": {"jax": jax.__version__,
                      "device_count": jax.device_count(),
                      "quick": bool(args.quick), "sweeps": sweeps,
                      **size},
            "serve_results": results}


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="CI sizing (tiny model, 3 sweeps)")
    ap.add_argument("--out", default="")
    args = ap.parse_args(argv)
    payload = run_bench(args)
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"wrote {args.out}")
    return payload


if __name__ == "__main__":
    sys.exit(0 if main() else 1)
