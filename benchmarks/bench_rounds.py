"""Round-engine sweep: participation rate x staleness x server optimizer.

For each cell the same synthetic federation is trained with the
round-based engine (`repro.core.rounds.RoundEngine`) and scored on
held-out data: ELBO perplexity (lower = better), NPMI coherence, and TSS
against the generative ground-truth topics.  The (participation=1.0,
fedavg, no-staleness) cell is the paper's Algorithm 1 baseline; every
other cell is a non-ideal regime from the related work
(arXiv:2311.00314 partial participation, async-FL staleness discounts).

Emits a JSON record per cell plus the sweep grid, e.g.:

    PYTHONPATH=src python -m benchmarks.bench_rounds \\
        --out experiments/bench_rounds.json --rounds 120

Small-scale smoke (used by tests/test_rounds.py):

    PYTHONPATH=src python -m benchmarks.bench_rounds --vocab 100 \\
        --topics 5 --docs 60 --rounds 5
"""
from __future__ import annotations

import argparse
import json
import os

import jax
import numpy as np

from repro.configs.base import NTM, FederatedConfig, ModelConfig, RoundConfig
from repro.core.ntm import prodlda
from repro.core.protocol import ClientState
from repro.core.rounds import RoundEngine
from repro.data.synthetic_lda import generate_lda_corpus
from repro.launch.simulate import heldout_elbo_per_token, heldout_perplexity
from repro.metrics import npmi_coherence, tss

PARTICIPATION = (1.0, 0.6, 0.4)
SERVER_OPTS = ("fedavg", "fedavgm", "fedadam")
STALENESS = ({"straggler_prob": 0.0, "max_staleness": 0},
             {"straggler_prob": 0.3, "max_staleness": 2})
# FedAdam steps are ~1/(sqrt(v)+tau) normalized; unit server_lr diverges
SERVER_LR = {"fedavg": 1.0, "fedavgm": 1.0, "fedadam": 0.05}


def run(out_path="experiments/bench_rounds.json", *, vocab=400, topics=10,
        docs=600, nodes=5, rounds=120, batch=64, lr=2e-3, seed=0,
        participation=PARTICIPATION, server_opts=SERVER_OPTS,
        staleness=STALENESS):
    syn = generate_lda_corpus(
        vocab_size=vocab, num_topics=topics, num_nodes=nodes,
        shared_topics=max(topics // 5, 1), docs_per_node=docs,
        val_docs_per_node=max(docs // 10, 20), seed=seed)
    cfg = ModelConfig(name="bench-rounds", kind=NTM, vocab_size=vocab,
                      num_topics=topics, ntm_hidden=(64, 64))
    # deterministic ELBO (no dropout / reparam noise): plain-SGD clients
    # are stable under it at small scale, same choice as tests/test_protocol
    loss_fn = lambda p, b: prodlda.elbo_loss(p, cfg, b, train=False)  # noqa: E731,E501
    init = prodlda.init_params(jax.random.PRNGKey(seed), cfg)
    clients = [ClientState(data={"bow": b}, num_docs=len(b))
               for b in syn.node_bows]
    fed = FederatedConfig(num_clients=nodes, learning_rate=lr,
                          max_rounds=rounds, rel_tol=0.0)
    val = syn.concat_val_bows()

    results = []
    for frac in participation:
        k = max(int(round(frac * nodes)), 1)
        for opt in server_opts:
            for stale in staleness:
                rc = RoundConfig(clients_per_round=k,
                                 sampling_seed=seed,
                                 server_optimizer=opt,
                                 server_lr=SERVER_LR.get(opt, 1.0),
                                 staleness_decay=0.5, **stale)
                eng = RoundEngine(loss_fn, init, clients, fed, rc,
                                  batch_size=batch)
                params = eng.fit(seed=seed)
                beta = np.asarray(prodlda.get_topics(params))
                rec = {"participation": frac,
                       "clients_per_round": k,
                       "server_optimizer": opt,
                       "server_lr": rc.server_lr,
                       **stale,
                       "rounds_run": len(eng.history),
                       "final_loss": eng.history[-1]["loss"],
                       "heldout_elbo_per_token": heldout_elbo_per_token(
                           params, cfg, val),
                       "heldout_perplexity": heldout_perplexity(
                           params, cfg, val),
                       "npmi_coherence": float(npmi_coherence(beta, val)),
                       "tss": float(tss(syn.beta, beta))}
                results.append(rec)
                print(f"K={k}/{nodes} {opt:8s} "
                      f"stale_p={stale['straggler_prob']:.1f}: "
                      f"ppl={rec['heldout_perplexity']:8.1f} "
                      f"npmi={rec['npmi_coherence']:+.3f} "
                      f"tss={rec['tss']:.2f}")

    payload = {"grid": {"participation": list(participation),
                        "server_optimizers": list(server_opts),
                        "staleness": list(staleness)},
               "setup": {"vocab": vocab, "topics": topics, "nodes": nodes,
                         "docs_per_node": docs, "rounds": rounds,
                         "batch": batch, "lr": lr, "seed": seed},
               "results": results}
    if out_path:
        os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
        with open(out_path, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"wrote {out_path} ({len(results)} cells)")
    return payload


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="experiments/bench_rounds.json")
    ap.add_argument("--vocab", type=int, default=400)
    ap.add_argument("--topics", type=int, default=10)
    ap.add_argument("--docs", type=int, default=600)
    ap.add_argument("--nodes", type=int, default=5)
    ap.add_argument("--rounds", type=int, default=120)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--lr", type=float, default=2e-3)
    ap.add_argument("--seed", type=int, default=0)
    a = ap.parse_args(argv)
    run(a.out, vocab=a.vocab, topics=a.topics, docs=a.docs, nodes=a.nodes,
        rounds=a.rounds, batch=a.batch, lr=a.lr, seed=a.seed)


if __name__ == "__main__":
    main()
