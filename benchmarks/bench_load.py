"""Latency-under-load benchmark for the federation wire — the
``load_results`` payload block.

A thin sizing wrapper over the multi-process drivers in
``repro.launch.federate_load``: a real asyncio server process, N real
client processes on real sockets.  Two cells, gated by
``benchmarks/ci_gate.py`` against the committed baseline (HARD on
correctness, warn-only on timing — the repo-wide two-tier policy):

* ``wire-sync-equivalence`` — the DESIGN.md §6 anchor crossed over the
  wire: M=K / ``max_staleness=0`` / in-order localhost uploads must
  reproduce the sync twin's ``Federation.run()`` trajectory.
  ``final_param_dev`` hard-fails at the repo-wide 1e-5 bound — encode
  → TCP → decode must be numerically invisible at fp32.
* ``wire-load`` — >= 4 concurrent client processes hammering the
  single-aggregation-worker front-end while inference interleaves:
  p50/p95/p99 upload + infer RTT and aggregations/s are the SLO
  columns (warn-only trend); hard-fails on any rejection reason
  outside ``REJECT_REASONS``, zero aggregations, zero inference
  calls, or fewer than 4 processes.

Usage (what .github/workflows/ci.yml runs):

    PYTHONPATH=src python -m benchmarks.bench_load --quick \\
        --out experiments/bench_load_ci.json
    python -m benchmarks.ci_gate experiments/bench_load_ci.json \\
        benchmarks/baselines/BENCH_scenarios_ci.json
"""
from __future__ import annotations

import argparse
import json
import os
import sys

import jax

from repro.api.spec import (DataSpec, ExecutionSpec, FederationSpec,
                            ModelSpec, ScheduleSpec, ServingSpec)
from repro.launch.federate_load import run_anchor, run_load


def base_wire_spec(*, vocab, topics, hidden, num_clients, docs, batch,
                   buffer_size, max_staleness) -> FederationSpec:
    # lr below the tiny-config divergence point (the bench_serve sizing
    # rule): the anchor compares absolute param devs
    return FederationSpec(
        name="bench-load",
        model=ModelSpec(vocab=vocab, topics=topics, hidden=hidden),
        data=DataSpec(num_clients=num_clients, docs_per_node=docs,
                      val_docs_per_node=8),
        schedule=ScheduleSpec(mode="buffered_async",
                              buffer_size=buffer_size,
                              max_staleness=max_staleness,
                              staleness_policy="polynomial"),
        execution=ExecutionSpec(exec_mode="loop", batch_size=batch,
                                learning_rate=2e-4),
        serving=ServingSpec(host="127.0.0.1", port=0,
                            wire_precision="fp32"))


def run_bench(args) -> dict:
    size = dict(vocab=64, topics=4, hidden=16, num_clients=8, docs=40,
                batch=16) if args.quick else \
        dict(vocab=200, topics=8, hidden=32, num_clients=12, docs=120,
             batch=32)
    sweeps = 2 if args.quick else 4
    anchor_sweeps = 2 if args.quick else 4
    procs = args.procs
    spec = base_wire_spec(**size, buffer_size=2,
                          max_staleness=2 * size["num_clients"])
    anchor = run_anchor(spec, sweeps=anchor_sweeps)
    anchor["cell"] = "wire-sync-equivalence"
    load = run_load(spec, procs=procs, sweeps=sweeps,
                    infer_every=3, infer_batch=4 if args.quick else 16)
    load["cell"] = "wire-load"
    results = [anchor, load]
    print(f"[wire-sync-equivalence] dev={anchor['final_param_dev']:.1e} "
          f"aggs={anchor['aggregations']} "
          f"upload_p50={anchor.get('upload_p50_s', float('nan')):.4f}s")
    print(f"[wire-load] procs={load['procs']} "
          f"{load['accepted']}/{load['uploads']} accepted "
          f"aggs/s={load['aggs_per_s']:.2f} "
          f"upload_p50={load.get('upload_p50_s', float('nan')):.4f}s "
          f"p99={load.get('upload_p99_s', float('nan')):.4f}s "
          f"infer_p50={load.get('infer_p50_s', float('nan')):.4f}s "
          f"rejections={load['rejections']}")
    return {"setup": {"jax": jax.__version__,
                      "device_count": jax.device_count(),
                      "quick": bool(args.quick), "sweeps": sweeps,
                      "anchor_sweeps": anchor_sweeps, "procs": procs,
                      **size},
            "load_results": results}


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="CI sizing (tiny model, 2 sweeps)")
    ap.add_argument("--procs", type=int, default=4,
                    help="concurrent client processes (the CI SLO cell "
                         "needs >= 4)")
    ap.add_argument("--out", default="")
    args = ap.parse_args(argv)
    payload = run_bench(args)
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"wrote {args.out}")
    return payload


if __name__ == "__main__":
    sys.exit(0 if main() else 1)
