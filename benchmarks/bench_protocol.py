"""Protocol microbenchmarks: per-round cost of the gFedNTM machinery.

Times (CPU wall-clock, jit-compiled steady state):
  * Eq. (2) aggregation over L clients,
  * secure-aggregation masking overhead,
  * top-k compression + error feedback,
  * one full federated round (ProdLDA) vs one centralized step,
  * FedAvg local-steps rounds (the collective-volume knob) — also reports
    the analytic bytes-on-the-wire per round for each mode.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import FederatedConfig
from repro.core.aggregation import (aggregate_host,
                                    compress_with_error_feedback,
                                    secure_mask_grads, topk_sparsify)
from repro.core.ntm import prodlda
from repro.core.protocol import (ClientState, FedAvgTrainer,
                                 FederatedTrainer)
from repro.data.synthetic_lda import generate_lda_corpus


def _time(fn, *args, n=20, **kw):
    fn(*args, **kw)   # compile / warm
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args, **kw)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n * 1e6   # us


def payload_bytes(tree) -> int:
    return sum(l.size * l.dtype.itemsize
               for l in jax.tree_util.tree_leaves(tree))


def run(quick=False):
    rows = []
    cfg = get_config("prodlda-synthetic").reduced()
    params = prodlda.init_params(jax.random.PRNGKey(0), cfg)
    n_clients = 5
    grads = [jax.tree_util.tree_map(
        lambda p: jnp.asarray(np.random.default_rng(i).standard_normal(
            p.shape), jnp.float32), params) for i in range(n_clients)]
    weights = [float(16 * (i + 1)) for i in range(n_clients)]

    agg = jax.jit(lambda gs: aggregate_host(gs, weights))
    rows.append(("aggregate_eq2_5clients", _time(agg, grads),
                 f"payload={payload_bytes(grads[0])}B"))

    mask = jax.jit(lambda g: secure_mask_grads(
        g, jax.random.PRNGKey(0), 2, n_clients, 16.0))
    rows.append(("secure_mask_per_client", _time(mask, grads[0]),
                 "pairwise PRG masks"))

    spars = jax.jit(lambda g: topk_sparsify(g, 0.1))
    rows.append(("topk_sparsify_10pct", _time(spars, grads[0]),
                 f"kept~{int(0.1 * payload_bytes(grads[0]))}B"))

    # full rounds
    syn = generate_lda_corpus(vocab_size=cfg.vocab_size,
                              num_topics=cfg.num_topics, num_nodes=3,
                              shared_topics=3, docs_per_node=200,
                              val_docs_per_node=20, seed=0)
    loss = lambda p, b: prodlda.elbo_loss(p, cfg, b, train=False)  # noqa
    clients = [ClientState(data={"bow": b}, num_docs=len(b))
               for b in syn.node_bows]
    fed = FederatedConfig(learning_rate=1e-2, max_rounds=3)
    tr = FederatedTrainer(loss, params, clients, fed, batch_size=32)
    tr.round()
    t0 = time.perf_counter()
    reps = 2 if quick else 5
    for _ in range(reps):
        tr.round()
    rows.append(("federated_round_syncopt",
                 (time.perf_counter() - t0) / reps * 1e6,
                 f"wire/round={2 * payload_bytes(params)}B"))

    fa = FedAvgTrainer(loss, params, clients,
                       FederatedConfig(learning_rate=1e-2, local_steps=4),
                       batch_size=32)
    fa.round()
    t0 = time.perf_counter()
    for _ in range(reps):
        fa.round()
    rows.append(("fedavg_round_4localsteps",
                 (time.perf_counter() - t0) / reps * 1e6,
                 f"wire/4steps={2 * payload_bytes(params)}B (4x less/step)"))
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
