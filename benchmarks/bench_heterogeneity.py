"""Beyond-paper ablation: client heterogeneity vs federated gain.

The paper varies heterogeneity via K' (shared topics) with hard
per-node topic ownership.  Real federations sit between IID and fully
partitioned; this ablation sweeps the standard Dirichlet-skew knob
(`repro.data.federated_split`, mode="dirichlet") over document-topic
labels and measures:
  * the federated model's TSS (recovery of the global topic set),
  * the mean non-collaborative TSS,
  * the federated-minus-noncollab gain,
at alpha in {10 (≈IID), 0.5, 0.05 (highly skewed)}.

Expected (and the paper's §4.1 implication): the federated GAIN grows as
clients become more skewed — federation matters most exactly when the
clients are most different.  Runs standalone:
    PYTHONPATH=src python -m benchmarks.bench_heterogeneity
"""
from __future__ import annotations

import argparse
import json
import os

import jax
import numpy as np

from repro.configs.base import NTM, FederatedConfig, ModelConfig
from repro.core.ntm import prodlda
from repro.core.protocol import (ClientState, FederatedTrainer,
                                 train_centralized)
from repro.data.federated_split import split_corpus_across_clients
from repro.data.synthetic_lda import generate_lda_corpus
from repro.metrics import tss
from repro.optim import adam


def run(out_path="experiments/bench_heterogeneity.json", *, vocab=400,
        topics=10, docs=900, steps=150, nodes=3, seed=0):
    # one pooled corpus with known ground truth; heterogeneity comes from
    # how documents are ASSIGNED to clients (label = dominant topic)
    syn = generate_lda_corpus(
        vocab_size=vocab, num_topics=topics, num_nodes=1,
        shared_topics=topics, docs_per_node=docs, val_docs_per_node=50,
        seed=seed)
    bows = syn.node_bows[0]
    labels = np.argmax(syn.node_thetas[0], axis=1)
    cfg = ModelConfig(name="het", kind=NTM, vocab_size=vocab,
                      num_topics=topics, ntm_hidden=(64, 64))
    loss = lambda p, b: prodlda.elbo_loss(p, cfg, b)  # noqa: E731

    results = []
    for alpha in (10.0, 0.5, 0.05):
        parts = split_corpus_across_clients(
            len(bows), nodes, mode="dirichlet", labels=labels,
            dirichlet_alpha=alpha, seed=seed)
        client_bows = [bows[p] for p in parts]

        # non-collaborative
        tss_nc = []
        for l, cb in enumerate(client_bows):
            init = prodlda.init_params(jax.random.PRNGKey(seed + 7 * l), cfg)
            p = train_centralized(loss, init, {"bow": cb},
                                  optimizer=adam(2e-3), batch_size=64,
                                  steps=steps, seed=seed + l)
            tss_nc.append(tss(syn.beta, np.asarray(prodlda.get_topics(p))))

        # federated (gFedNTM)
        init = prodlda.init_params(jax.random.PRNGKey(seed + 99), cfg)
        clients = [ClientState(data={"bow": cb}, num_docs=len(cb))
                   for cb in client_bows]
        tr = FederatedTrainer(
            loss, init, clients,
            FederatedConfig(learning_rate=2e-3, max_rounds=steps,
                            rel_tol=0.0),
            optimizer=adam(2e-3), batch_size=64)
        fed = tr.fit(seed=seed)
        tss_fed = tss(syn.beta, np.asarray(prodlda.get_topics(fed)))

        rec = {"dirichlet_alpha": alpha,
               "tss_federated": tss_fed,
               "tss_noncollab_mean": float(np.mean(tss_nc)),
               "gain": tss_fed - float(np.mean(tss_nc)),
               "client_sizes": [int(len(p)) for p in parts]}
        results.append(rec)
        print(f"alpha={alpha:<5} sizes={rec['client_sizes']} "
              f"TSS fed={tss_fed:.2f} nc={rec['tss_noncollab_mean']:.2f} "
              f"gain={rec['gain']:+.2f}")

    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2)
    return results


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--steps", type=int, default=150)
    args = ap.parse_args(argv)
    run(steps=args.steps)


if __name__ == "__main__":
    main()
